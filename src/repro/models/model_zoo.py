"""Uniform model API over all assigned architectures.

``build(cfg, n_slots)`` returns a :class:`Model` whose methods cover the four
assigned shapes: ``loss_fn`` (train_4k), ``prefill`` (prefill_32k),
``decode_step`` (decode_32k / long_500k). ``input_specs`` produces
ShapeDtypeStruct stand-ins for every input — weak-type-correct, shardable, no
device allocation (the dry-run path).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import Family, ModelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.dist.sharding import ParamSpec, ShardingCtx
from repro.models import encdec, hybrid, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    n_slots: int
    _params: dict
    _loss: Callable
    _prefill: Callable
    _decode: Callable
    _cache_specs: Callable

    # ---- parameters --------------------------------------------------
    def param_specs(self) -> dict:
        return self._params

    def init(self, rng: jax.Array) -> dict:
        return shd.tree_init(rng, self._params)

    def abstract_params(self) -> dict:
        return shd.tree_abstract(self._params)

    def param_shardings(self, ctx: ShardingCtx):
        return shd.tree_shardings(self._params, ctx)

    def param_pspecs(self, ctx: ShardingCtx):
        return shd.tree_pspecs(self._params, ctx)

    # ---- compute -----------------------------------------------------
    def loss_fn(self, params, batch, ctx: ShardingCtx, **kw):
        return self._loss(params, batch, self.cfg, ctx, **kw)

    def prefill(self, params, batch, ctx: ShardingCtx, s_max=None, **kw):
        return self._prefill(params, batch, self.cfg, ctx, s_max=s_max, **kw)

    def decode_step(self, params, cache, tokens, pos, ctx: ShardingCtx, **kw):
        return self._decode(params, cache, tokens, pos, self.cfg, ctx, **kw)

    # ---- caches & inputs ----------------------------------------------
    def cache_specs(self, batch: int, s_max: int) -> dict:
        return self._cache_specs(self.cfg, batch, s_max)

    def abstract_cache(self, batch: int, s_max: int):
        return shd.tree_abstract(self.cache_specs(batch, s_max))

    def cache_shardings(self, batch: int, s_max: int, ctx: ShardingCtx):
        return shd.tree_shardings(self.cache_specs(batch, s_max), ctx)

    def init_cache(self, batch: int, s_max: int):
        import numpy as np
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype or jnp.bfloat16),
            self.cache_specs(batch, s_max),
            is_leaf=lambda x: isinstance(x, ParamSpec))

    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "decode":
            batch: dict[str, Any] = {
                "tokens": sds((B, 1), i32),
                "cache": self.abstract_cache(B, S),
                "pos": sds((), i32),
            }
            return batch
        s_text = S
        batch = {}
        if cfg.family == Family.VLM:
            s_text = S - cfg.n_patches
            batch["patches"] = sds((B, cfg.n_patches, cfg.d_patch), jnp.bfloat16)
        if cfg.family == Family.ENCDEC:
            batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = sds((B, s_text), i32)
        if shape.kind == "train":
            batch["targets"] = sds((B, s_text), i32)
        return batch

    def input_pspecs(self, shape: ShapeConfig, ctx: ShardingCtx):
        """PartitionSpecs matching input_specs structure (batch-sharded)."""
        from jax.sharding import PartitionSpec as P
        def leaf_spec(path_leaf):
            sds = path_leaf
            axes = ("batch",) + (None,) * (len(sds.shape) - 1)
            return ctx.spec(axes, sds.shape)

        specs = self.input_specs(shape)
        if shape.kind == "decode":
            cache_ps = shd.tree_pspecs(self.cache_specs(
                shape.global_batch, shape.seq_len), ctx)
            return {"tokens": ctx.spec(("batch", None), specs["tokens"].shape),
                    "cache": cache_ps,
                    "pos": P()}
        return jax.tree.map(leaf_spec, specs)

    def demo_batch(self, shape: ShapeConfig, rng=None) -> dict:
        """Materialized random batch (smoke tests / examples)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        specs = self.input_specs(shape)

        def mk(rng, s):
            if jnp.issubdtype(s.dtype, jnp.integer):
                return jax.random.randint(rng, s.shape, 0, max(self.cfg.vocab, 2),
                                          s.dtype)
            return jax.random.normal(rng, s.shape, jnp.float32).astype(s.dtype)

        leaves, treedef = jax.tree.flatten(specs)
        rngs = jax.random.split(rng, len(leaves))
        if shape.kind == "decode":
            out = jax.tree.unflatten(treedef, [mk(r, s) for r, s in
                                               zip(rngs, leaves)])
            out["cache"] = self.init_cache(shape.global_batch, shape.seq_len)
            out["pos"] = jnp.asarray(min(shape.seq_len - 1, 7), jnp.int32)
            return out
        return jax.tree.unflatten(treedef, [mk(r, s) for r, s in
                                            zip(rngs, leaves)])


def build(cfg: ModelConfig, n_slots: int = 1,
          moe_replicate: bool = False) -> Model:
    if cfg.family in (Family.DENSE, Family.MOE, Family.VLM):
        params = transformer.lm_params(cfg, n_slots, moe_replicate)
        return Model(cfg, n_slots, params, transformer.loss_fn,
                     transformer.prefill, transformer.decode_step,
                     transformer.cache_specs)
    if cfg.family in (Family.SSM, Family.HYBRID):
        if cfg.family == Family.SSM:
            # pure-SSM = hybrid with a single degenerate super-block period:
            # reuse the mamba assembly without shared attention.
            from repro.models import mamba_lm
            return Model(cfg, n_slots, mamba_lm.lm_params(cfg),
                         mamba_lm.loss_fn, mamba_lm.prefill,
                         mamba_lm.decode_step, mamba_lm.cache_specs)
        return Model(cfg, n_slots, hybrid.hybrid_params(cfg), hybrid.loss_fn,
                     hybrid.prefill, hybrid.decode_step, hybrid.cache_specs)
    if cfg.family == Family.ENCDEC:
        return Model(cfg, n_slots, encdec.encdec_params(cfg), encdec.loss_fn,
                     encdec.prefill, encdec.decode_step, encdec.cache_specs)
    raise ValueError(cfg.family)
