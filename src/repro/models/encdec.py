"""Whisper-style encoder-decoder backbone (conv frontend is a stub by
assignment: ``input_specs()`` supplies precomputed frame embeddings).

Encoder: bidirectional attention + sinusoidal positions over 1500 frames.
Decoder: causal self-attention + cross-attention, learned positions (table
extended to the assigned 32k decode length), LayerNorm, GELU MLP, tied head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, ShardingCtx
from repro.models import attention as A
from repro.models import layers as L
from repro.models.transformer import stack_specs

MAX_POS = 32_768  # assigned decode_32k length


def enc_block_params(cfg: ModelConfig) -> dict:
    return {"ln1": L.norm_params(cfg.d_model, "layer"),
            "attn": A.attn_params(cfg),
            "ln2": L.norm_params(cfg.d_model, "layer"),
            "mlp": L.mlp_params(cfg)}


def dec_block_params(cfg: ModelConfig) -> dict:
    return {"ln1": L.norm_params(cfg.d_model, "layer"),
            "self_attn": A.attn_params(cfg),
            "ln_x": L.norm_params(cfg.d_model, "layer"),
            "cross_attn": A.attn_params(cfg),
            "ln2": L.norm_params(cfg.d_model, "layer"),
            "mlp": L.mlp_params(cfg)}


def encdec_params(cfg: ModelConfig) -> dict:
    return {
        "embed": L.embed_params(cfg),
        "pos": ParamSpec((MAX_POS, cfg.d_model), (None, "embed"), scale=0.02),
        "enc_blocks": stack_specs(enc_block_params(cfg), cfg.n_encoder_layers),
        "enc_norm": L.norm_params(cfg.d_model, "layer"),
        "dec_blocks": stack_specs(dec_block_params(cfg), cfg.n_layers),
        "dec_norm": L.norm_params(cfg.d_model, "layer"),
    }


def encode(params: dict, frames: jax.Array, cfg: ModelConfig,
           ctx: ShardingCtx, remat: str = "block") -> jax.Array:
    """frames (B, S_enc, d_model) — the conv-stub output."""
    S = frames.shape[1]
    h = frames + L.sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)
    h = ctx.constrain(h, "batch", "seq", None)

    def block(h, pl):
        a, _ = A.attend_full(pl["attn"], L.apply_norm(pl["ln1"], h, cfg.norm_eps),
                             cfg, ctx, causal=False)
        h = h + a
        h = h + L.apply_mlp(pl["mlp"], L.apply_norm(pl["ln2"], h, cfg.norm_eps),
                            cfg, ctx)
        return h, None

    if remat != "none":
        block = jax.checkpoint(block)
    h, _ = jax.lax.scan(block, h, params["enc_blocks"], unroll=ctx.unroll)
    return L.apply_norm(params["enc_norm"], h, cfg.norm_eps)


def decode_train(params: dict, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ModelConfig, ctx: ShardingCtx, *,
                 remat: str = "block", collect_cache: bool = False):
    """Teacher-forced decoder pass; optionally collects self+cross caches."""
    B, S = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, ctx)
    h = h + params["pos"][:S][None].astype(h.dtype)
    h = ctx.constrain(h, "batch", "seq", None)

    def block(h, pl):
        a, self_kv = A.attend_full(
            pl["self_attn"], L.apply_norm(pl["ln1"], h, cfg.norm_eps), cfg, ctx,
            causal=True)
        h = h + a
        ckv = A.cross_kv(pl["cross_attn"], enc_out)
        c, _ = A.attend_full(
            pl["cross_attn"], L.apply_norm(pl["ln_x"], h, cfg.norm_eps), cfg,
            ctx, cross_kv=ckv)
        h = h + c
        h = h + L.apply_mlp(pl["mlp"], L.apply_norm(pl["ln2"], h, cfg.norm_eps),
                            cfg, ctx)
        if collect_cache:
            k, v = self_kv
            caches = ({"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)},
                      {"k": ckv[0].astype(jnp.bfloat16),
                       "v": ckv[1].astype(jnp.bfloat16)})
            return h, caches
        return h, None

    if remat != "none":
        block = jax.checkpoint(block)
    h, ys = jax.lax.scan(block, h, params["dec_blocks"], unroll=ctx.unroll)
    h = L.apply_norm(params["dec_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    if collect_cache:
        return logits, ys
    return logits


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx,
            **kw):
    enc = encode(params, batch["frames"].astype(jnp.bfloat16), cfg, ctx,
                 remat=kw.get("remat", "block"))
    logits = decode_train(params, batch["tokens"], enc, cfg, ctx,
                          remat=kw.get("remat", "block"))
    ce = L.cross_entropy(logits, batch["targets"])
    return ce, {"ce": ce, "aux_loss": jnp.zeros(()), "drop_frac": jnp.zeros(())}


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    self_c = stack_specs(A.cache_spec(cfg, batch, s_max), cfg.n_layers)
    cross_c = stack_specs(A.cache_spec(cfg, batch, cfg.encoder_seq),
                          cfg.n_layers)
    return {"self": self_c, "cross": cross_c}


def prefill(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx,
            s_max: int | None = None, **kw):
    """Encode + teacher-forced decoder prefill → (last logits, caches, pos)."""
    enc = encode(params, batch["frames"].astype(jnp.bfloat16), cfg, ctx,
                 remat=kw.get("remat", "block"))
    logits, (self_c, cross_c) = decode_train(
        params, batch["tokens"], enc, cfg, ctx, collect_cache=True,
        remat=kw.get("remat", "block"))
    S = batch["tokens"].shape[1]
    s_max = s_max or S
    if s_max > S:
        pad = s_max - S
        self_c = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            self_c)
    return logits[:, -1:], {"self": self_c, "cross": cross_c}, S


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig, ctx: ShardingCtx, **_):
    B = tokens.shape[0]
    h = L.embed_tokens(params["embed"], tokens, ctx)
    h = h + jax.lax.dynamic_slice_in_dim(params["pos"], pos, 1)[None].astype(h.dtype)

    def block(h, xs):
        pl, sk, sv, ck, cv = xs
        a, new_self = A.decode_attend(
            pl["self_attn"], L.apply_norm(pl["ln1"], h, cfg.norm_eps),
            {"k": sk, "v": sv}, pos, cfg, ctx, use_rope=False)
        h = h + a
        c = A.decode_cross_attend(
            pl["cross_attn"], L.apply_norm(pl["ln_x"], h, cfg.norm_eps),
            {"k": ck, "v": cv}, cfg, ctx)
        h = h + c
        h = h + L.apply_mlp(pl["mlp"], L.apply_norm(pl["ln2"], h, cfg.norm_eps),
                            cfg, ctx)
        return h, new_self

    h, new_self = jax.lax.scan(
        block, h, (params["dec_blocks"], cache["self"]["k"], cache["self"]["v"],
                   cache["cross"]["k"], cache["cross"]["v"]),
        unroll=ctx.unroll)
    h = L.apply_norm(params["dec_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    return logits, {"self": new_self, "cross": cache["cross"]}
