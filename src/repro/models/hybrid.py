"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block
(arXiv:2411.15242). The shared block attends over concat(h, h0) (2·d_model)
— h0 = the initial embeddings — and is applied after every
``shared_attn_every`` Mamba layers with shared weights (per-invocation LoRA
deltas omitted; recorded in DESIGN.md).

Structure: scan over ``n_super = n_layers // every`` super-blocks; each
super-block is an inner scan over ``every`` Mamba layers followed by the
shared block. Caches: mamba (n_super, every, ...) + shared-attn KV
(n_super, ...) — distinct state per invocation, shared weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, ShardingCtx
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import stack_specs


def _shared_block_params(cfg: ModelConfig) -> dict:
    d2 = 2 * cfg.d_model
    return {"ln1": L.norm_params(d2),
            "attn": A.attn_params(cfg, d_in=d2, d_out=cfg.d_model),
            "ln2": L.norm_params(cfg.d_model),
            "mlp": L.mlp_params(cfg)}


def hybrid_params(cfg: ModelConfig) -> dict:
    every = cfg.shared_attn_every
    n_super = cfg.n_layers // every
    mamba = stack_specs(stack_specs(
        {"ln": L.norm_params(cfg.d_model), "mix": S.ssm_params(cfg)}, every),
        n_super)
    return {"embed": L.embed_params(cfg),
            "mamba": mamba,
            "shared": _shared_block_params(cfg),
            "final_norm": L.norm_params(cfg.d_model)}


def _apply_shared(ps: dict, h, h0, cfg: ModelConfig, ctx: ShardingCtx,
                  positions):
    x2 = jnp.concatenate([h, h0], axis=-1)
    a, kv = A.attend_full(ps["attn"], L.apply_norm(ps["ln1"], x2, cfg.norm_eps),
                          cfg, ctx, causal=True, rope_positions=positions)
    h = h + a
    h = h + L.apply_mlp(ps["mlp"], L.apply_norm(ps["ln2"], h, cfg.norm_eps),
                        cfg, ctx)
    return h, kv


def forward(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx, *,
            remat: str = "block", collect_cache: bool = False,
            cache_len: int | None = None, **_):
    h = L.embed_tokens(params["embed"], batch["tokens"], ctx)
    h0 = h
    B, Sq, _ = h.shape
    positions = jnp.arange(Sq)[None, :]

    def mamba_layer(h, pl):
        out, cache = S.apply_ssm(pl["mix"], L.apply_norm(pl["ln"], h,
                                                         cfg.norm_eps), cfg, ctx)
        return h + out, cache if collect_cache else None

    def super_block(carry, pl):
        h, h0 = carry
        h, mcache = jax.lax.scan(mamba_layer, h, pl, unroll=ctx.unroll)
        h, kv = _apply_shared(params["shared"], h, h0, cfg, ctx, positions)
        if collect_cache:
            k, v = kv
            clen = cache_len or Sq
            acache = {"k": k[:, -clen:].astype(jnp.bfloat16),
                      "v": v[:, -clen:].astype(jnp.bfloat16)}
            return (h, h0), (mcache, acache)
        return (h, h0), None

    if remat != "none":
        super_block = jax.checkpoint(super_block)
    (h, _), ys = jax.lax.scan(super_block, (h, h0), params["mamba"],
                              unroll=ctx.unroll)
    h = L.apply_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    stats = {"aux_loss": jnp.zeros(()), "drop_frac": jnp.zeros(())}
    if collect_cache:
        return logits, stats, ys
    return logits, stats


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx,
            **kw):
    logits, stats = forward(params, batch, cfg, ctx,
                            remat=kw.get("remat", "block"))
    ce = L.cross_entropy(logits, batch["targets"])
    return ce, {"ce": ce, **stats}


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    every = cfg.shared_attn_every
    n_super = cfg.n_layers // every
    mamba = stack_specs(stack_specs(S.ssm_cache_spec(cfg, batch), every),
                        n_super)
    attn = stack_specs(A.cache_spec(cfg, batch, s_max), n_super)
    return {"mamba": mamba, "attn": attn}


def prefill(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx,
            s_max: int | None = None, **kw):
    Sq = batch["tokens"].shape[1]
    s_max = s_max or Sq
    logits, _, (mcache, acache) = forward(
        params, batch, cfg, ctx, collect_cache=True, cache_len=s_max,
        remat=kw.get("remat", "block"))
    if s_max > Sq:
        pad = s_max - Sq
        acache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            acache)
    return logits[:, -1:], {"mamba": mcache, "attn": acache}, Sq


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig, ctx: ShardingCtx, **_):
    h = L.embed_tokens(params["embed"], tokens, ctx)
    h0 = h

    def mamba_layer(h, xs):
        pl, conv_c, state_c = xs
        out, cache = S.decode_ssm(pl["mix"],
                                  L.apply_norm(pl["ln"], h, cfg.norm_eps),
                                  {"conv": conv_c, "state": state_c}, cfg, ctx)
        return h + out, cache

    def super_block(h, xs):
        pl, mconv, mstate, ak, av = xs
        h, mcache = jax.lax.scan(mamba_layer, h,
                                 (pl, mconv, mstate), unroll=ctx.unroll)
        x2 = jnp.concatenate([h, h0], axis=-1)
        a, new_kv = A.decode_attend(
            params["shared"]["attn"],
            L.apply_norm(params["shared"]["ln1"], x2, cfg.norm_eps),
            {"k": ak, "v": av}, pos, cfg, ctx)
        h = h + a
        h = h + L.apply_mlp(params["shared"]["mlp"],
                            L.apply_norm(params["shared"]["ln2"], h,
                                         cfg.norm_eps), cfg, ctx)
        return h, (mcache, {"k": new_kv["k"], "v": new_kv["v"]})

    h, (mcache, acache) = jax.lax.scan(
        super_block, h,
        (params["mamba"], cache["mamba"]["conv"], cache["mamba"]["state"],
         cache["attn"]["k"], cache["attn"]["v"]), unroll=ctx.unroll)
    h = L.apply_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    return logits, {"mamba": mcache, "attn": acache}
