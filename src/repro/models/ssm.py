"""Mamba-2 (SSD) block — arXiv:2405.21060.

Projections → short causal depthwise conv (k=4) on (x, B, C) → SSD chunked
scan (kernels/ssd_scan) → gated RMSNorm → output projection.

Decode carries {"conv": (B, K-1, d_in + 2N) pre-activation window,
"state": (B, H, P, N) SSM state} per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, ShardingCtx
from repro.kernels import api as K
from repro.models import layers as L


def ssm_params(cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nh = s.n_heads(d)
    N = s.d_state
    Kc = s.conv_kernel
    return {
        "wz": ParamSpec((d, d_in), ("embed", "d_inner")),
        "wx": ParamSpec((d, d_in), ("embed", "d_inner")),
        "wB": ParamSpec((d, N), ("embed", None)),
        "wC": ParamSpec((d, N), ("embed", None)),
        "wdt": ParamSpec((d, nh), ("embed", "ssm_heads")),
        "conv": ParamSpec((Kc, d_in + 2 * N), (None, None), scale=0.5,
                          dtype=jnp.float32),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros",
                           dtype=jnp.float32),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros",
                             dtype=jnp.float32),
        "norm": ParamSpec((d_in,), (None,), init="ones", dtype=jnp.float32),
        "wo": ParamSpec((d_in, d), ("d_inner", "embed")),
    }


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv via K shifted adds. u (B,S,C); w (K,C)."""
    Kc = w.shape[0]
    out = u * w[Kc - 1][None, None, :].astype(u.dtype)
    for i in range(1, Kc):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :u.shape[1]]
        out = out + shifted * w[Kc - 1 - i][None, None, :].astype(u.dtype)
    return out


def _split_proj(p: dict, x: jax.Array):
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    return z, xs, Bm, Cm, dt


def _gated_norm(y: jax.Array, z: jax.Array, w: jax.Array,
                eps: float) -> jax.Array:
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return L.rms_norm(g, w, eps)


def apply_ssm(p: dict, x: jax.Array, cfg: ModelConfig,
              ctx: ShardingCtx) -> jax.Array:
    """Full-sequence Mamba-2 mixer (train / prefill). Returns (y, cache)."""
    s = cfg.ssm
    B, S, d = x.shape
    d_in = s.d_inner(d)
    nh = s.n_heads(d)

    z, xs, Bm, Cm, dt_raw = _split_proj(p, x)
    u = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_tail = u[:, -(s.conv_kernel - 1):, :]          # decode conv window
    u = jax.nn.silu(_causal_conv(u, p["conv"]).astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(u, [d_in, d_in + s.d_state], axis=-1)
    xs = ctx.constrain(xs, "batch", None, "d_inner")

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, nh, s.head_dim)
    xh = ctx.constrain(xh, "batch", None, "ssm_heads", None)
    y, state = K.ssd_scan(xh, dt, A, Bm, Cm, p["D"], chunk=s.chunk_size)
    y = y.reshape(B, S, d_in)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y.reshape(B * S, d_in),
                     p["wo"]).reshape(B, S, d)
    cache = {"conv": conv_tail.astype(jnp.bfloat16),
             "state": state.astype(jnp.float32)}
    return out, cache


def ssm_cache_spec(cfg: ModelConfig, batch: int) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    return {
        "conv": ParamSpec((batch, s.conv_kernel - 1, s.d_inner(d) + 2 * s.d_state),
                          ("batch", None, None), dtype=jnp.bfloat16,
                          init="zeros"),
        "state": ParamSpec((batch, s.n_heads(d), s.head_dim, s.d_state),
                           ("batch", "ssm_heads", None, None),
                           dtype=jnp.float32, init="zeros"),
    }


def decode_ssm(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig,
               ctx: ShardingCtx):
    """One-token recurrent step. x (B,1,d) → (y (B,1,d), cache)."""
    s = cfg.ssm
    B, _, d = x.shape
    d_in = s.d_inner(d)
    nh = s.n_heads(d)

    z, xs, Bm, Cm, dt_raw = _split_proj(p, x)
    u_t = jnp.concatenate([xs, Bm, Cm], axis=-1)[:, 0]   # (B, C)
    win = jnp.concatenate([cache["conv"].astype(u_t.dtype),
                           u_t[:, None]], axis=1)        # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv"].astype(u_t.dtype))
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_conv = win[:, 1:]

    xs_t, B_t, C_t = jnp.split(conv_out, [d_in, d_in + s.d_state], axis=-1)
    dt_t = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                           + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    y_t, state = K.ssd_decode_step(
        cache["state"], xs_t.reshape(B, nh, s.head_dim), dt_t, A, B_t, C_t,
        p["D"])
    y = _gated_norm(y_t.reshape(B, 1, d_in), z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    return out, {"conv": new_conv.astype(jnp.bfloat16),
                 "state": state.astype(jnp.float32)}
