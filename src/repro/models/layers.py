"""Shared layers: norms, RoPE, MLP variants — pure functions over param trees.

Params are declared as :class:`ParamSpec` trees (dist/sharding.py) so the same
definition materializes real arrays (smoke tests), ShapeDtypeStructs (dry-run)
and PartitionSpecs (mesh lowering).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, ShardingCtx


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_params(d: int, kind: str = "rms") -> dict:
    if kind == "rms":
        return {"w": ParamSpec((d,), (None,), init="ones", dtype=jnp.float32)}
    return {"w": ParamSpec((d,), (None,), init="ones", dtype=jnp.float32),
            "b": ParamSpec((d,), (None,), init="zeros", dtype=jnp.float32)}


def apply_norm(p: dict, x: jax.Array, eps: float) -> jax.Array:
    if "b" in p:
        return layer_norm(x, p["w"], p["b"], eps)
    return rms_norm(x, p["w"], eps)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D) with D even; positions broadcastable to (..., S)."""
    D = x.shape[-1]
    half = D // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freq  # (..., S, 1, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S: int, d: int) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe


# ----------------------------------------------------------------------
# MLP (SwiGLU / GELU / squared-ReLU) — dense feed-forward
# ----------------------------------------------------------------------
def mlp_params(cfg: ModelConfig, d: int | None = None,
               d_ff: int | None = None) -> dict:
    d = d or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    p = {"up": ParamSpec((d, d_ff), ("embed", "ff")),
         "down": ParamSpec((d_ff, d), ("ff", "embed"))}
    if cfg.mlp_variant == "swiglu":
        p["gate"] = ParamSpec((d, d_ff), ("embed", "ff"))
    return p


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig,
              ctx: ShardingCtx) -> jax.Array:
    up = jnp.einsum("...d,df->...f", x, p["up"])
    if cfg.mlp_variant == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, p["gate"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.mlp_variant == "relu2":
        r = jax.nn.relu(up.astype(jnp.float32))
        h = (r * r).astype(x.dtype)
    else:  # gelu2
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    h = ctx.constrain(h, "batch", "seq", "ff") if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, p["down"])


# ----------------------------------------------------------------------
# Embeddings / LM head
# ----------------------------------------------------------------------
def embed_params(cfg: ModelConfig) -> dict:
    p = {"tok": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                          scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return p


def embed_tokens(p: dict, tokens: jax.Array, ctx: ShardingCtx) -> jax.Array:
    h = jnp.take(p["tok"], tokens, axis=0)
    return ctx.constrain(h, "batch", "seq", None)


def lm_logits(p: dict, h: jax.Array, ctx: ShardingCtx) -> jax.Array:
    if "head" in p:
        logits = jnp.einsum("...d,dv->...v", h, p["head"])
    else:
        logits = jnp.einsum("...d,vd->...v", h, p["tok"])
    return logits


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean CE in fp32; targets < 0 are ignored (in addition to mask)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    tgt = jnp.clip(targets, 0, logits.shape[-1] - 1)
    picked = jnp.take_along_axis(lf, tgt[..., None], axis=-1)[..., 0]
    nll = lse - picked
    valid = (targets >= 0)
    if mask is not None:
        valid &= mask.astype(bool)
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom
