from repro.models.model_zoo import Model, build  # noqa: F401
