"""Decoder-only transformer LM assembly (dense / MoE / VLM).

Layer parameters are *stacked* (leading n_layers dim) and the forward pass is
a ``lax.scan`` over layers — compile time stays O(1) in depth at 512 devices.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import Family, ModelConfig
from repro.dist.sharding import ParamSpec, ShardingCtx
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M

AUX_COEF = 0.01


def stack_specs(tree, n: int):
    def f(s: ParamSpec):
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, dtype=s.dtype,
                         init=s.init, scale=s.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def block_params(cfg: ModelConfig, n_slots: int = 1,
                 moe_replicate: bool = False) -> dict:
    p = {"ln1": L.norm_params(cfg.d_model),
         "attn": A.attn_params(cfg),
         "ln2": L.norm_params(cfg.d_model)}
    if cfg.moe.enabled:
        p["moe"] = M.moe_params(cfg, n_slots, replicate=moe_replicate)
        if cfg.moe.dense_residual:
            p["mlp"] = L.mlp_params(cfg)
    else:
        p["mlp"] = L.mlp_params(cfg)
    return p


def lm_params(cfg: ModelConfig, n_slots: int = 1,
              moe_replicate: bool = False) -> dict:
    p = {"embed": L.embed_params(cfg),
         "blocks": stack_specs(block_params(cfg, n_slots, moe_replicate),
                               cfg.n_layers),
         "final_norm": L.norm_params(cfg.d_model)}
    if cfg.family == Family.VLM:
        p["patch_proj"] = ParamSpec((cfg.d_patch, cfg.d_model),
                                    (None, "embed"))
    return p


def _apply_ffn(pl: dict, h: jax.Array, keys, cfg: ModelConfig,
               ctx: ShardingCtx, moe_opts: dict):
    """The post-attention half of a block. Returns (delta, aux, drop)."""
    hn = L.apply_norm(pl["ln2"], h, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    drop = jnp.zeros((), jnp.float32)
    out = jnp.zeros_like(h)
    if cfg.moe.enabled:
        mo, aux, drop = M.apply_moe(pl["moe"], hn, keys, cfg, ctx, **moe_opts)
        out = out + mo
        if cfg.moe.dense_residual:
            out = out + L.apply_mlp(pl["mlp"], hn, cfg, ctx)
    else:
        out = out + L.apply_mlp(pl["mlp"], hn, cfg, ctx)
    return out, aux, drop


def _embed_inputs(params: dict, batch: dict, cfg: ModelConfig,
                  ctx: ShardingCtx):
    """tokens (+ VLM patches) → (h, token_keys)."""
    tokens = batch["tokens"]
    h = L.embed_tokens(params["embed"], tokens, ctx)
    keys = tokens
    if cfg.family == Family.VLM and "patches" in batch:
        pe = jnp.einsum("bpc,cd->bpd", batch["patches"].astype(h.dtype),
                        params["patch_proj"])
        h = jnp.concatenate([pe, h], axis=1)
        keys = jnp.concatenate(
            [jnp.zeros(pe.shape[:2], tokens.dtype), tokens], axis=1)
    h = ctx.constrain(h, "batch", "seq", None)
    return h, keys


def forward(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx, *,
            remat: str = "block", collect_cache: bool = False,
            cache_len: int | None = None, moe_opts: dict | None = None,
            attn_opts: dict | None = None):
    """Full-sequence forward. Returns (logits, aux) or with cache when
    collect_cache (prefill)."""
    moe_opts = moe_opts or {}
    attn_opts = attn_opts or {}
    h, keys = _embed_inputs(params, batch, cfg, ctx)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]

    def block(h, pl):
        h = ctx.constrain(h, "batch", "seq", None)
        a, kv = A.attend_full(pl["attn"], L.apply_norm(pl["ln1"], h, cfg.norm_eps),
                              cfg, ctx, causal=True, rope_positions=positions,
                              window=cfg.swa_window, **attn_opts)
        h = h + a
        delta, aux, drop = _apply_ffn(pl, h, keys, cfg, ctx, moe_opts)
        h = h + delta
        h = ctx.constrain(h, "batch", "seq", None)
        if collect_cache:
            clen = cache_len or A.cache_len(cfg, S)
            k, v = kv
            cache = {"k": k[:, -clen:].astype(jnp.bfloat16),
                     "v": v[:, -clen:].astype(jnp.bfloat16)}
            return h, (aux, drop, cache)
        return h, (aux, drop)

    if remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat == "dots" else None)
        block = jax.checkpoint(block, policy=policy)

    h, ys = jax.lax.scan(block, h, params["blocks"], unroll=ctx.unroll)
    if collect_cache:
        aux, drop, cache = ys
    else:
        aux, drop = ys
    h = L.apply_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    logits = ctx.constrain(logits, "batch", "seq", None)
    stats = {"aux_loss": aux.sum(), "drop_frac": drop.mean()}
    if collect_cache:
        return logits, stats, cache
    return logits, stats


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx,
            **fwd_kw):
    logits, stats = forward(params, batch, cfg, ctx, **fwd_kw)
    targets = batch["targets"]
    if cfg.family == Family.VLM and "patches" in batch:
        pad = -jnp.ones((targets.shape[0], batch["patches"].shape[1]),
                        targets.dtype)
        targets = jnp.concatenate([pad, targets], axis=1)
    ce = L.cross_entropy(logits, targets)
    loss = ce + AUX_COEF * stats["aux_loss"]
    return loss, {"ce": ce, **stats}


# ----------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    per_layer = A.cache_spec(cfg, batch, s_max)
    return stack_specs(per_layer, cfg.n_layers)


def prefill(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx,
            s_max: int | None = None, **fwd_kw):
    """Returns (last-token logits, cache, pos). The cache is sized/aligned for
    continuation at position ``pos``: padded to the target cache length and,
    for sliding-window ring caches, rolled so slot j holds position ≡ j (mod W).
    """
    S = batch["tokens"].shape[1]
    if cfg.family == Family.VLM and "patches" in batch:
        S += batch["patches"].shape[1]
    clen = A.cache_len(cfg, s_max or S)
    logits, stats, cache = forward(
        params, batch, cfg, ctx, collect_cache=True,
        cache_len=min(clen, S), **fwd_kw)
    # stacked cache layout: (L, B, S_c, KV, hd) — seq axis 2
    if cfg.swa_window and S > clen and S % clen:
        cache = jax.tree.map(lambda c: jnp.roll(c, S % clen, axis=2), cache)
    if S < clen:
        cache = jax.tree.map(
            lambda c: jnp.pad(c, ((0, 0), (0, 0), (0, clen - S), (0, 0),
                                  (0, 0))), cache)
    return logits[:, -1:], cache, S


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: ModelConfig, ctx: ShardingCtx,
                moe_opts: dict | None = None):
    """One-token step. tokens (B,1); pos scalar. Returns (logits, cache)."""
    moe_opts = moe_opts or {}
    h, keys = _embed_inputs(params, {"tokens": tokens}, cfg, ctx)

    def block(h, xs):
        pl, kc, vc = xs
        a, new_cache = A.decode_attend(
            pl["attn"], L.apply_norm(pl["ln1"], h, cfg.norm_eps),
            {"k": kc, "v": vc}, pos, cfg, ctx)
        h = h + a
        delta, _, _ = _apply_ffn(pl, h, keys, cfg, ctx, moe_opts)
        return h + delta, new_cache

    h, new_cache = jax.lax.scan(block, h, (params["blocks"], cache["k"],
                                           cache["v"]), unroll=ctx.unroll)
    h = L.apply_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    return logits, new_cache
