"""Mixture-of-Experts block with StreamShield WeakHash routing and
Group-Rescale-confined expert-parallel dispatch.

Expert placement ("slots"): the expert dimension is laid out over ``n_slots``
device slots (= the size of the dispatch axis group):

* ``experts_per_slot = E // n_slots`` when E >= n_slots (arctic: 128/16 = 8);
* otherwise each expert is **TP-split across ``slots_per_expert`` slots**
  (mixtral: 8 experts × 2 slots, each slot holding half of d_ff). SwiGLU is
  elementwise in d_ff, so per-slot partial down-projections sum exactly.

Weights are stored pre-slotted as (n_slots, eps, d, ff_slot); the dispatch
all-to-all is confined to the slot axes (default: the ICI-contiguous
``"model"`` axis — the paper's Group-Rescale; the §Perf baseline alternative
is a global ("data","model") dispatch).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, ShardingCtx
from repro.kernels import api as K
from repro.kernels.weakhash_route.ref import positions_in_bucket


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoELayout:
    n_experts: int
    n_slots: int
    # replicate=True (serving): when n_slots > E each slot holds a FULL copy
    # of one expert; a token is dispatched to a single replica chosen by
    # WeakHash (bounded candidate set = the expert's replicas, dynamic
    # load/hash selection — the paper's key-to-task relaxation). Physical
    # weight duplication; the content-addressed checkpoint dedups it.
    # replicate=False (training): slots TP-split d_ff instead (exact math,
    # partial down-projections sum; every send goes to all splits).
    replicate: bool = False

    @property
    def experts_per_slot(self) -> int:
        return max(1, self.n_experts // self.n_slots)

    @property
    def slots_per_expert(self) -> int:
        return max(1, self.n_slots // self.n_experts)

    def ff_slot(self, d_ff: int) -> int:
        return d_ff if self.replicate else d_ff // self.slots_per_expert


def serve_replicate(cfg: ModelConfig) -> bool:
    """Serving expert layout rule: replicate a full expert per slot when the
    per-device copy (one expert × n_layers, bf16) fits a ~8 GiB budget —
    WeakHash replica selection then keeps dispatch to 1 send/assignment.
    Otherwise fall back to ff-split slots (mixtral-8x22b: 8 × 16384 experts
    would be 33.8 GiB/device replicated)."""
    per_dev = (cfg.n_layers * cfg.mlp_mats * cfg.d_model
               * cfg.moe.d_ff_expert * 2)
    return per_dev <= 8 * 2**30


def moe_params(cfg: ModelConfig, n_slots: int = 1,
               replicate: bool = False) -> dict:
    m = cfg.moe
    lay = MoELayout(m.n_experts, n_slots, replicate)
    d, ffs, eps = cfg.d_model, lay.ff_slot(m.d_ff_expert), lay.experts_per_slot
    mats = cfg.mlp_mats
    p = {
        "router": ParamSpec((d, m.n_experts), ("embed", None),
                            dtype=jnp.float32, scale=0.02),
        "up": ParamSpec((n_slots, eps, d, ffs), ("expert", None, "embed", None)),
        "down": ParamSpec((n_slots, eps, ffs, d), ("expert", None, None, "embed")),
    }
    if mats == 3:
        p["gate"] = ParamSpec((n_slots, eps, d, ffs),
                              ("expert", None, "embed", None))
    return p


def _expert_ffn(cfg: ModelConfig, p: dict, x):
    """x (..., eps, C, d) with weights (..., eps, d, ffs) → (..., eps, C, d)."""
    up = jnp.einsum("...ecd,...edf->...ecf", x, p["up"])
    if "gate" in p:
        g = jnp.einsum("...ecd,...edf->...ecf", x, p["gate"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * up
    elif cfg.mlp_variant == "relu2":
        r = jax.nn.relu(up.astype(jnp.float32))
        h = (r * r).astype(x.dtype)
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...ecf,...efd->...ecd", h, p["down"])


# ----------------------------------------------------------------------
# Local (single-device / no-mesh) path — also the numeric oracle for the
# distributed path (tests compare them with generous capacities).
# ----------------------------------------------------------------------
def _local_moe(p: dict, x, token_keys, cfg: ModelConfig, *, mode: str,
               rescue: bool, capacity_factor: float):
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    cap = _round_up(max(int(T * m.top_k * capacity_factor / m.n_experts), 4), 4)
    route = K.weakhash_route(
        logits, top_k=m.top_k, capacity=cap, n_groups=m.n_groups, mode=mode,
        token_keys=None if token_keys is None else token_keys.reshape(-1),
        rescue=rescue)
    buf = K.dispatch(xt, route, m.n_experts, cap)      # (E, C, d)
    n_slots, eps = p["up"].shape[0], p["up"].shape[1]
    w = {k: p[k].reshape(n_slots * eps, p[k].shape[2], p[k].shape[3])
         for k in ("up", "down", "gate") if k in p}
    assert w["up"].shape[0] == m.n_experts, "local path expects n_slots*eps == E"
    out = _expert_ffn(cfg, w, buf)
    y = K.combine(out, route, T)
    drop = 1.0 - route.keep.mean()
    return y.reshape(B, S, d), route.aux_loss, drop


# ----------------------------------------------------------------------
# Distributed (shard_map) path: WeakHash route → slot dispatch →
# group-limited all-to-all → per-slot expert FFN → reverse all-to-all.
# ----------------------------------------------------------------------
def apply_moe(p: dict, x, token_keys, cfg: ModelConfig, ctx: ShardingCtx, *,
              mode: str = "weakhash", rescue: bool = True,
              slot_axes: tuple[str, ...] = ("model",),
              replicate: bool = False,
              capacity_factor: float | None = None,
              capacity_floor: int = 4):
    """x (B, S, d) → (y, aux_loss, drop_fraction).

    mode "strict" = paper-baseline top-k routing; "weakhash" = StreamShield
    group-restricted, load-aware routing. rescue=True re-routes capacity
    overflow (γ=full); False drops it (γ=partial). replicate: serving layout
    (full expert copy per slot, WeakHash replica selection).
    """
    m = cfg.moe
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    if ctx.mesh is None:
        return _local_moe(p, x, token_keys, cfg, mode=mode, rescue=rescue,
                          capacity_factor=cf)

    mesh = ctx.mesh
    slot_axes = tuple(a for a in slot_axes if a in mesh.shape)
    n_slots = math.prod(mesh.shape[a] for a in slot_axes)
    assert p["up"].shape[0] == n_slots, (p["up"].shape, n_slots)
    lay = MoELayout(m.n_experts, n_slots, replicate)

    B, S, d = x.shape
    from repro.dist.sharding import batch_axes_for
    batch_axes = batch_axes_for(mesh, B)
    bspec = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))
    seq_shardable = ctx.sequence_parallel and S % ctx.axis_size("model") == 0
    x_spec = P(bspec, "model" if seq_shardable else None, None)
    w_spec = P("model" if "model" in slot_axes else slot_axes, None, None, None)
    # slots laid out over ("data","model") for the global-dispatch baseline
    if len(slot_axes) > 1:
        w_spec = P(slot_axes, None, None, None)

    batch_shards = math.prod(mesh.shape[a] for a in batch_axes) \
        if batch_axes else 1
    t_local = (B * S) // (batch_shards
                          * (ctx.axis_size("model") if seq_shardable else 1))
    sends = 1 if lay.replicate else lay.slots_per_expert
    fl = max(capacity_floor, 1)
    c_send = _round_up(
        max(math.ceil(t_local * m.top_k * sends * cf / n_slots), fl), fl)
    c_local = _round_up(
        max(math.ceil(n_slots * c_send * cf / lay.experts_per_slot), fl), fl)

    wr = p["router"]
    args = [p["up"], p["down"]]
    specs = [w_spec, w_spec]
    if "gate" in p:
        args.append(p["gate"])
        specs.append(w_spec)

    keys = token_keys if token_keys is not None else jnp.zeros((B, S), jnp.int32)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(x_spec, P(x_spec[0], x_spec[1]), P(None, None),
                       *specs),
             out_specs=(x_spec, P(), P()), check_vma=False)
    def run(x_l, keys_l, wr_l, up_l, down_l, *maybe_gate):
        w_l = {"up": up_l[0], "down": down_l[0]}
        if maybe_gate:
            w_l["gate"] = maybe_gate[0][0]
        b_l, s_l, _ = x_l.shape
        T = b_l * s_l
        xt = x_l.reshape(T, d)
        logits = xt.astype(jnp.float32) @ wr_l
        cap_e = _round_up(
            max(math.ceil(T * m.top_k * cf / m.n_experts), 2), 2)
        route = K.weakhash_route(
            logits, top_k=m.top_k, capacity=cap_e, n_groups=m.n_groups,
            mode=mode, token_keys=keys_l.reshape(-1), rescue=rescue)

        e = route.expert_idx                                    # (T, k)
        keep0 = route.keep
        if lay.slots_per_expert == 1:
            slot = e // lay.experts_per_slot                    # (T, k)
            local_e = e % lay.experts_per_slot
        elif lay.replicate:
            # WeakHash replica selection: each expert has spe full replicas;
            # the candidate set is bounded and the pick is a cheap hash of
            # (token key, k-index) — deterministic, diffuses hot experts.
            spe = lay.slots_per_expert
            kk = keys_l.reshape(-1)[:, None].astype(jnp.uint32)
            kk = kk * jnp.uint32(2654435761) + jnp.arange(
                e.shape[1], dtype=jnp.uint32)[None, :] * jnp.uint32(40503)
            replica = (kk % jnp.uint32(spe)).astype(e.dtype)
            slot = e * spe + replica                            # (T, k)
            local_e = jnp.zeros_like(slot)
        else:
            spe = lay.slots_per_expert
            slot = (e[..., None] * spe
                    + jnp.arange(spe, dtype=e.dtype)).reshape(T, -1)
            local_e = jnp.zeros_like(slot)
            keep0 = jnp.repeat(keep0, spe, axis=-1)
        n_sends = slot.shape[-1]

        pos = positions_in_bucket(slot.reshape(-1), n_slots)
        keep = keep0.reshape(-1) & (pos < c_send)
        sl, pos_c = slot.reshape(-1), jnp.clip(pos, 0, c_send - 1)

        payload = jnp.zeros((n_slots, c_send, d), x_l.dtype)
        src = jnp.repeat(xt, n_sends, axis=0)
        payload = payload.at[sl, pos_c].add(
            src * keep[:, None].astype(x_l.dtype), mode="drop")
        meta = jnp.full((n_slots, c_send), 0, jnp.int32)
        meta = meta.at[sl, pos_c].max(
            jnp.where(keep, local_e.reshape(-1), 0), mode="drop")

        a2a = partial(jax.lax.all_to_all, axis_name=slot_axes
                      if len(slot_axes) > 1 else slot_axes[0],
                      split_axis=0, concat_axis=0, tiled=True)
        recv = a2a(payload)                                     # (n_slots, C, d)
        recv_e = a2a(meta)

        if lay.experts_per_slot == 1:
            # one expert per slot: every received row belongs to it — no
            # second-level scatter, no capacity inflation (§Perf: removes
            # phantom-row FFN compute, biggest at decode shapes)
            buf = recv.reshape(1, n_slots * c_send, d)
            out = _expert_ffn(cfg, w_l, buf)                    # (1, n·C, d)
            back = a2a(out.reshape(n_slots, c_send, d))         # at source
        else:
            # second-level dispatch into this slot's experts
            flat = recv.reshape(n_slots * c_send, d)
            fe = recv_e.reshape(-1)
            pos2 = positions_in_bucket(fe, lay.experts_per_slot)
            keep2 = pos2 < c_local
            buf = jnp.zeros((lay.experts_per_slot, c_local, d), x_l.dtype)
            buf = buf.at[fe, jnp.clip(pos2, 0, c_local - 1)].add(
                flat * keep2[:, None].astype(x_l.dtype), mode="drop")

            out = _expert_ffn(cfg, w_l, buf)                    # (eps, C2, d)

            back = out[fe, jnp.clip(pos2, 0, c_local - 1)]
            back = back * keep2[:, None].astype(back.dtype)
            back = a2a(back.reshape(n_slots, c_send, d))        # at source

        rows = back[sl, pos_c] * keep[:, None].astype(back.dtype)
        w_tok = route.weights
        if lay.slots_per_expert > 1 and not lay.replicate:
            w_tok = jnp.repeat(w_tok, lay.slots_per_expert, axis=-1)
        y = (rows.reshape(T, n_sends, d)
             * w_tok[..., None].astype(back.dtype)).sum(axis=1)

        # tokens vary over the batch axes (+ "model" when sequence-sharded);
        # pmean only over varying axes (vma-checked by shard_map)
        red_axes = batch_axes + (("model",) if seq_shardable else ())
        aux = jax.lax.pmean(route.aux_loss, red_axes)
        drop = jax.lax.pmean(1.0 - (keep0.reshape(-1) & (pos < c_send)
                                    ).mean(), red_axes)
        return y.reshape(b_l, s_l, d), aux, drop

    return run(x, keys, wr, *args)
