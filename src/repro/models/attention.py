"""GQA attention: train/prefill (flash) and decode (incl. the distributed-LSE
path for KV-sequence-sharded caches).

Cache layout per layer: k/v (B, S_max, KV, D); a single scalar ``pos`` (fill
level) is carried by the model. Sliding-window archs use a ring cache of
length ``min(window, S_max)``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.sharding import ParamSpec, ShardingCtx
from repro.kernels import api as K
from repro.models import layers as L


def attn_params(cfg: ModelConfig, d_in: int | None = None,
                d_out: int | None = None) -> dict:
    d_in = d_in or cfg.d_model
    d_out = d_out or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d_in, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d_in, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d_in, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, d_out), ("heads", "head_dim", "embed")),
    }


def _qkv(p: dict, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    return q, k, v


def attend_full(p: dict, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx, *,
                causal: bool = True,
                rope_positions: jax.Array | None = None,
                cross_kv: tuple[jax.Array, jax.Array] | None = None,
                window: int = 0,
                exact_blocks: bool = False,
                chunk: int = 512) -> jax.Array:
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    if cross_kv is None:
        q, k, v = _qkv(p, x)
        if rope_positions is not None:
            q = L.rope(q, rope_positions, cfg.rope_theta)
            k = L.rope(k, rope_positions, cfg.rope_theta)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k, v = cross_kv
        causal = False
    # attention computes with heads sharded (seq gathered); ctx falls back to
    # no head sharding when H % model != 0 (arctic) — XLA then keeps seq sharded.
    q = ctx.constrain(q, "batch", None, "heads", None)
    k = ctx.constrain(k, "batch", None, "kv_heads", None)
    v = ctx.constrain(v, "batch", None, "kv_heads", None)
    out = K.flash_attention(q, k, v, causal=causal, window=window,
                            chunk=chunk, exact_blocks=exact_blocks,
                            unroll=ctx.unroll)
    out = ctx.constrain(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, (k, v)


def cross_kv(p: dict, enc: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Precompute encoder K/V for cross-attention (cached for decode)."""
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    return k, v


# ----------------------------------------------------------------------
# Decode
# ----------------------------------------------------------------------
def cache_len(cfg: ModelConfig, s_max: int) -> int:
    if cfg.swa_window:
        return min(cfg.swa_window, s_max)
    return s_max


def cache_spec(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    """ParamSpec tree for one layer's KV cache (stacked by caller)."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    slen = cache_len(cfg, s_max)
    # kv_heads preferred; kv_seq is the fallback (distributed-LSE decode)
    # when n_kv_heads does not divide the model axis (kv ∈ {1, 8} archs).
    axes = ("batch", "kv_seq", "kv_heads", None)
    return {
        "k": ParamSpec((batch, slen, KV, hd), axes, dtype=jnp.bfloat16),
        "v": ParamSpec((batch, slen, KV, hd), axes, dtype=jnp.bfloat16),
    }


def decode_attend(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                  cfg: ModelConfig, ctx: ShardingCtx, *,
                  use_rope: bool = True) -> tuple[jax.Array, dict]:
    """One-token self-attention decode. x (B,1,d); pos scalar = absolute
    position. Returns (out (B,1,d), updated cache)."""
    q, k_new, v_new = _qkv(p, x)
    positions = jnp.asarray(pos)[None, None]
    if use_rope and cfg.rope_theta:
        q = L.rope(q, positions, cfg.rope_theta)
        k_new = L.rope(k_new, positions, cfg.rope_theta)

    slen = cache["k"].shape[1]
    write_at = (pos % slen) if cfg.swa_window else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, write_at, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, write_at, 0, 0))
    valid = jnp.minimum(pos + 1, slen)

    out = _decode_core(q, k, v, valid, cfg, ctx)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": k, "v": v}


def decode_cross_attend(p: dict, x: jax.Array, cross_cache: dict,
                        cfg: ModelConfig, ctx: ShardingCtx) -> jax.Array:
    """One-token cross-attention against a precomputed encoder K/V cache."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])  # no RoPE on cross
    o = _decode_core(q, cross_cache["k"], cross_cache["v"],
                     cross_cache["k"].shape[1], cfg, ctx)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _decode_core(q, k, v, valid_len, cfg: ModelConfig, ctx: ShardingCtx):
    """Dispatch between head-sharded decode and KV-seq-sharded distributed LSE."""
    KV = cfg.n_kv_heads
    if ctx.mesh is None or ctx.divides("kv_heads", KV) \
            or not ctx.divides("kv_seq", k.shape[1]):
        q = ctx.constrain(q, "batch", None, "heads", None)
        k = ctx.constrain(k, "batch", None, "kv_heads", None)
        v = ctx.constrain(v, "batch", None, "kv_heads", None)
        o = K.decode_attention(q, k, v, kv_valid_len=valid_len)
        return o[:, None]  # (B,1,H,D)
    return _distributed_decode(q, k, v, valid_len, ctx)


def _distributed_decode(q, k, v, valid_len, ctx: ShardingCtx):
    """KV cache sharded on sequence over "model": per-shard partial softmax,
    merged with a distributed log-sum-exp (flash-decode across chips)."""
    mesh = ctx.mesh
    from repro.dist.sharding import batch_axes_for
    batch_axes = batch_axes_for(mesh, q.shape[0])
    bspec = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))
    kv_spec = P(bspec, "model", None, None)
    q_spec = P(bspec, None, None, None)

    k = jax.lax.with_sharding_constraint(
        k, jax.sharding.NamedSharding(mesh, kv_spec))
    v = jax.lax.with_sharding_constraint(
        v, jax.sharding.NamedSharding(mesh, kv_spec))

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(q_spec, kv_spec, kv_spec, P()),
             out_specs=q_spec, check_vma=False)
    def f(q_l, k_l, v_l, valid):
        idx = jax.lax.axis_index("model")
        s_local = k_l.shape[1]
        o, m, l = K.decode_attention_partial(
            q_l, k_l, v_l, kv_valid_len=valid, k_offset=idx * s_local)
        os = jax.lax.all_gather(o, "model")   # (16, B, H, D)
        ms = jax.lax.all_gather(m, "model")
        ls = jax.lax.all_gather(l, "model")
        return K.merge_partials(os, ms, ls)[:, None]

    return f(q, k, v, jnp.asarray(valid_len, jnp.int32))
