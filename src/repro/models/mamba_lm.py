"""Pure Mamba-2 LM (mamba2-1.3b): embeddings + N SSD blocks, scan-stacked."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import ShardingCtx
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import stack_specs


def lm_params(cfg: ModelConfig) -> dict:
    block = {"ln": L.norm_params(cfg.d_model), "mix": S.ssm_params(cfg)}
    return {"embed": L.embed_params(cfg),
            "blocks": stack_specs(block, cfg.n_layers),
            "final_norm": L.norm_params(cfg.d_model)}


def forward(params: dict, batch: dict, cfg: ModelConfig, ctx: ShardingCtx, *,
            remat: str = "block", collect_cache: bool = False, **_):
    h = L.embed_tokens(params["embed"], batch["tokens"], ctx)

    def block(h, pl):
        out, cache = S.apply_ssm(pl["mix"],
                                 L.apply_norm(pl["ln"], h, cfg.norm_eps),
                                 cfg, ctx)
        return h + out, cache if collect_cache else None

    if remat != "none":
        block = jax.checkpoint(block)
    h, caches = jax.lax.scan(block, h, params["blocks"], unroll=ctx.unroll)
    h = L.apply_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    stats = {"aux_loss": jnp.zeros(()), "drop_frac": jnp.zeros(())}
    if collect_cache:
        return logits, stats, caches
    return logits, stats


def loss_fn(params, batch, cfg: ModelConfig, ctx: ShardingCtx, **kw):
    logits, stats = forward(params, batch, cfg, ctx,
                            remat=kw.get("remat", "block"))
    ce = L.cross_entropy(logits, batch["targets"])
    return ce, {"ce": ce, **stats}


def cache_specs(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    del s_max  # SSM state is O(1) in sequence length
    return stack_specs(S.ssm_cache_spec(cfg, batch), cfg.n_layers)


def prefill(params, batch, cfg: ModelConfig, ctx: ShardingCtx, s_max=None,
            **kw):
    logits, _, caches = forward(params, batch, cfg, ctx, collect_cache=True,
                                remat=kw.get("remat", "block"))
    return logits[:, -1:], caches, batch["tokens"].shape[1]


def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                ctx: ShardingCtx, **_):
    h = L.embed_tokens(params["embed"], tokens, ctx)

    def block(h, xs):
        pl, conv_c, state_c = xs
        out, new_cache = S.decode_ssm(
            pl["mix"], L.apply_norm(pl["ln"], h, cfg.norm_eps),
            {"conv": conv_c, "state": state_c}, cfg, ctx)
        return h + out, new_cache

    h, new_cache = jax.lax.scan(block, h,
                                (params["blocks"], cache["conv"],
                                 cache["state"]), unroll=ctx.unroll)
    h = L.apply_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_logits(params["embed"], h, ctx)
    return logits, new_cache
