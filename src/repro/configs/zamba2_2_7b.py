"""zamba2-2.7b — assigned architecture config (public literature).

Selectable via ``--arch zamba2-2.7b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family=Family.HYBRID,
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=160,          # shared block attends over concat(h, h0) = 5120
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64,
                  conv_kernel=4, chunk_size=256),
    shared_attn_every=6,
    source="[arXiv:2411.15242; hf] Mamba2 + shared attn blocks",
)
