"""phi-3-vision-4.2b — assigned architecture config (public literature).

Selectable via ``--arch phi-3-vision-4.2b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family=Family.VLM,
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    n_patches=576,
    d_patch=1024,          # CLIP ViT-L/14 stub embedding width
    rope_theta=10_000.0,
    source="[hf:microsoft/Phi-3-vision-128k-instruct; hf] phi3-mini + CLIP",
)
