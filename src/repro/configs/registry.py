"""Architecture / shape registry — the ``--arch <id>`` / ``--shape <id>`` lookup."""
from __future__ import annotations

from repro.configs import base
from repro.configs.archs import ALL_ARCHS
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, SHAPES

ARCHS: dict[str, ModelConfig] = {m.name: m for m in ALL_ARCHS}


class UnknownArchError(KeyError):
    pass


def get_arch(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise UnknownArchError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}") from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise UnknownArchError(
            f"unknown shape {name!r}; available: {sorted(SHAPES)}") from None


def get_smoke_arch(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests / probe jobs."""
    return base.reduced(get_arch(name))


def cell_applicable(model: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) dry-run cell runs, and why not if skipped.

    Policy from the assignment: ``long_500k`` needs sub-quadratic attention —
    run for SSM/hybrid/SWA archs, skip (with a recorded note) for pure
    full-attention archs.
    """
    if shape.name == "long_500k" and not model.subquadratic:
        return False, (f"{model.name} uses full attention; 512k-token decode "
                       "cache is quadratic-prefill territory — skipped per "
                       "assignment (see DESIGN.md §Arch-applicability)")
    return True, ""


def make_run(arch: str, shape: str, *, multi_pod: bool = False,
             **overrides) -> RunConfig:
    model = get_arch(arch)
    optimizer = overrides.pop("optimizer", None)
    if optimizer is None:
        # Adam fp32 moments for arctic-480b exceed one pod's HBM; Adafactor
        # is the production choice there (DESIGN.md §3).
        name = "adafactor" if model.param_count() > 200e9 else "adamw"
        optimizer = base.OptimizerConfig(name=name)
    return RunConfig(model=model, shape=get_shape(shape), optimizer=optimizer,
                     multi_pod=multi_pod, **overrides)


def all_cells() -> list[tuple[str, str, bool, str]]:
    """All 40 (arch × shape) cells: (arch, shape, runs, skip_reason)."""
    cells = []
    for m in ALL_ARCHS:
        for s in SHAPES.values():
            ok, why = cell_applicable(m, s)
            cells.append((m.name, s.name, ok, why))
    return cells
