"""stablelm-1.6b — assigned architecture config (public literature).

Selectable via ``--arch stablelm-1.6b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family=Family.DENSE,
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    rope_theta=10_000.0,
    source="[hf:stabilityai/stablelm-2-1_6b; unverified]",
)
