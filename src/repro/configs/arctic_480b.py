"""arctic-480b — assigned architecture config (public literature).

Selectable via ``--arch arctic-480b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family=Family.MOE,
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,             # dense residual MLP hidden
    vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, n_groups=16),
    rope_theta=10_000.0,
    source="[hf:Snowflake/snowflake-arctic-base; hf]",
)
