"""stablelm-12b — assigned architecture config (public literature).

Selectable via ``--arch stablelm-12b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family=Family.DENSE,
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    rope_theta=10_000.0,
    source="[hf:stabilityai/stablelm-2-12b; hf]",
)
