"""mamba2-1.3b — assigned architecture config (public literature).

Selectable via ``--arch mamba2-1.3b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family=Family.SSM,
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64,
                  conv_kernel=4, chunk_size=256),
    source="[arXiv:2405.21060; unverified] SSD",
)
