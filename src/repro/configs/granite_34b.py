"""granite-34b — assigned architecture config (public literature).

Selectable via ``--arch granite-34b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family=Family.DENSE,
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,          # MQA
    head_dim=128,
    d_ff=24576,
    vocab=49152,
    mlp_variant="gelu2",
    tie_embeddings=True,
    rope_theta=10_000.0,
    source="[arXiv:2405.04324; hf] llama-arch, code",
)
