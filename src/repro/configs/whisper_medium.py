"""whisper-medium — assigned architecture config (public literature).

Selectable via ``--arch whisper-medium``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family=Family.ENCDEC,
    n_layers=24,           # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    mlp_variant="gelu2",
    tie_embeddings=True,
    n_encoder_layers=24,
    encoder_seq=1500,      # conv frontend stub emits 1500 frame embeddings
    source="[arXiv:2212.04356; unverified] enc-dec, conv frontend (stub)",
)
