"""Aggregates the ten assigned architecture configs (one module each)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.arctic_480b import CONFIG as ARCTIC_480B
from repro.configs.stablelm_1_6b import CONFIG as STABLELM_1_6B
from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.stablelm_12b import CONFIG as STABLELM_12B
from repro.configs.granite_34b import CONFIG as GRANITE_34B
from repro.configs.mamba2_1_3b import CONFIG as MAMBA2_1_3B
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_2_7B
from repro.configs.whisper_medium import CONFIG as WHISPER_MEDIUM
from repro.configs.phi_3_vision_4_2b import CONFIG as PHI3_VISION_4_2B

ALL_ARCHS: tuple[ModelConfig, ...] = (
    MIXTRAL_8X22B,
    ARCTIC_480B,
    STABLELM_1_6B,
    MINITRON_8B,
    STABLELM_12B,
    GRANITE_34B,
    MAMBA2_1_3B,
    ZAMBA2_2_7B,
    WHISPER_MEDIUM,
    PHI3_VISION_4_2B,
)
