"""Configuration dataclasses for StreamShield-JAX.

Every assigned architecture is described by a :class:`ModelConfig`; the four
assigned input shapes by :class:`ShapeConfig`; resiliency policy by
:class:`SLOConfig` (the paper's Table I encoded as data); and a full run by
:class:`RunConfig`.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
from typing import Any


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"
    HYBRID = "hybrid"
    ENCDEC = "encdec"   # audio backbone (whisper): encoder-decoder
    VLM = "vlm"         # vision-language: decoder LM + patch-embedding stub


class Completeness(str, enum.Enum):
    """γ in the paper's SLO triple: data-completeness requirement."""
    FULL = "full"
    PARTIAL = "partial"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    # Arctic-style dense residual MLP running in parallel with the experts.
    dense_residual: bool = False
    # --- StreamShield WeakHash / Group-Rescale routing parameters ---
    # Number of disjoint expert groups. Routing (WeakHash) restricts each
    # token's candidate experts to one group; dispatch (Group-Rescale) keeps
    # the all-to-all confined to the device group owning that expert group.
    n_groups: int = 1
    # Capacity factor for expert buffers (tokens per expert relative to even).
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 0
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk_size: int = 256

    @property
    def enabled(self) -> bool:
        return self.d_state > 0

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    moe: MoEConfig = dataclasses.field(default_factory=MoEConfig)
    ssm: SSMConfig = dataclasses.field(default_factory=SSMConfig)
    # Sliding-window attention width (0 = full attention).
    swa_window: int = 0
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # MLP variant: "swiglu" (3 mats), "gelu2"/"relu2" (2 mats, GELU / squared-ReLU).
    mlp_variant: str = "swiglu"
    # Hybrid (zamba2): a single shared attention block applied every
    # `shared_attn_every` SSM layers on concat(h, h0).
    shared_attn_every: int = 0
    # Encoder-decoder (whisper): encoder depth/seq; decoder uses n_layers.
    n_encoder_layers: int = 0
    encoder_seq: int = 0
    # VLM (phi-3-vision): patch-embedding stub dims.
    n_patches: int = 0
    d_patch: int = 0
    tie_embeddings: bool = False
    source: str = ""  # provenance string: [source; verified-tier]

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == Family.SSM

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode (long_500k) is feasible."""
        if self.family in (Family.SSM, Family.HYBRID):
            return True
        return self.swa_window > 0  # sliding-window attention bounds the cache

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec: decoder)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory napkins)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab * d  # input embedding
        if not self.tie_embeddings:
            total += self.vocab * d
        for _ in range(1):  # per-layer cost, multiplied below
            pass
        per_layer = 0
        if self.family in (Family.DENSE, Family.MOE, Family.VLM):
            per_layer += self._attn_params(d)
            per_layer += self._mlp_params(d)
            per_layer += 2 * d  # norms
            total += L * per_layer
        elif self.family == Family.SSM:
            total += L * (self._ssm_params(d) + d)
        elif self.family == Family.HYBRID:
            total += L * (self._ssm_params(d) + d)
            # shared attention block on 2d input (applied k times, one copy)
            d2 = 2 * d
            shared = (d2 * self.n_heads * self.head_dim  # q
                      + 2 * d2 * self.n_kv_heads * self.head_dim  # kv
                      + self.n_heads * self.head_dim * d  # o -> d
                      + 3 * d * self.d_ff + 2 * d2 + d)
            total += shared
        elif self.family == Family.ENCDEC:
            enc_layer = self._attn_params(d) + self._mlp_params(d) + 2 * d
            dec_layer = 2 * self._attn_params(d) + self._mlp_params(d) + 3 * d
            total += self.n_encoder_layers * enc_layer + L * dec_layer
        if self.family == Family.VLM:
            total += self.d_patch * d  # patch projector
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        if not self.moe.enabled:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        expert = 3 * d * self.moe.d_ff_expert
        inactive = L * (self.moe.n_experts - self.moe.top_k) * expert
        return self.param_count() - inactive

    def _attn_params(self, d: int) -> int:
        return (d * self.n_heads * self.head_dim
                + 2 * d * self.n_kv_heads * self.head_dim
                + self.n_heads * self.head_dim * d)

    @property
    def mlp_mats(self) -> int:
        return 3 if self.mlp_variant == "swiglu" else 2

    def _mlp_params(self, d: int) -> int:
        if self.moe.enabled:
            p = self.moe.n_experts * self.mlp_mats * d * self.moe.d_ff_expert
            p += d * self.moe.n_experts  # router
            if self.moe.dense_residual:
                p += self.mlp_mats * d * self.d_ff
            return p
        return self.mlp_mats * d * self.d_ff

    def _ssm_params(self, d: int) -> int:
        s = self.ssm
        d_in = s.d_inner(d)
        nh = s.n_heads(d)
        return (d * (2 * d_in + 2 * s.d_state + nh)   # in_proj -> z,x,B,C,dt
                + s.conv_kernel * (d_in + 2 * s.d_state)  # conv over x,B,C
                + 2 * nh                                # A_log, D
                + d_in                                  # gated norm
                + d_in * d)                             # out_proj

    def fingerprint(self) -> str:
        payload = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """The paper's SLO triple S = (γ, λ_max, τ_max)."""
    gamma: Completeness = Completeness.FULL
    lambda_max_s: float = 60.0    # max end-to-end latency
    tau_max_s: float = 60.0       # max recovery time after an abnormal event

    @property
    def recovery_tier(self) -> str:
        if self.tau_max_s < 1.0:
            return "sub_second"
        if self.tau_max_s <= 60.0:
            return "sub_minute"
        return "hour_level"


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"          # "adamw" | "adafactor" | "sgdm"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


@dataclasses.dataclass(frozen=True)
class ShardingOverrides:
    """Beyond-baseline sharding knobs, used by the §Perf hillclimb."""
    sequence_parallel: bool = True
    # Remat policy: "none" | "block" | "minimal" (nothing saveable)
    remat: str = "block"
    # Expert placement: "auto" | "ep" | "tp"
    expert_mode: str = "auto"
    # Confine MoE all-to-all to the model axis (Group-Rescale) vs global.
    grouped_a2a: bool = True
    # Microbatch count for gradient accumulation (1 = off).
    microbatches: int = 1
    # Cast parameters gathered for compute to bf16 (fp32 master kept by opt).
    compute_dtype: str = "bfloat16"
    # --- §Perf hillclimb knobs (defaults = paper-faithful baseline) ---
    # Minimum per-slot dispatch capacity (decode cells: floor 4 wastes ~50×
    # compute at batch≈1 token/device; hillclimb drops it to 1).
    moe_capacity_floor: int = 4
    # Cast gradients to bf16 before the cross-replica reduction (halves the
    # dominant all-reduce bytes; error feedback not needed at step scale).
    grad_reduce_bf16: bool = False
    # Exact causal attention blocks (skip fully-masked kv chunks) instead of
    # masked full-width chunks — removes the 2× causal flops waste.
    exact_attn_blocks: bool = False


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    sharding: ShardingOverrides = dataclasses.field(default_factory=ShardingOverrides)
    multi_pod: bool = False
    seed: int = 0

    def fingerprint(self) -> str:
        payload = json.dumps(
            {
                "model": self.model.fingerprint(),
                "shape": dataclasses.asdict(self.shape),
                "sharding": dataclasses.asdict(self.sharding),
                "optimizer": dataclasses.asdict(self.optimizer),
                "multi_pod": self.multi_pod,
            },
            sort_keys=True,
        )
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


def reduced(model: ModelConfig, **overrides: Any) -> ModelConfig:
    """A small same-family config for CPU smoke tests / probe jobs."""
    small: dict[str, Any] = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(model.n_kv_heads, 4) if model.n_kv_heads else 4),
        head_dim=16,
        d_ff=128,
        vocab=256,
    )
    if model.moe.enabled:
        small["moe"] = MoEConfig(
            n_experts=4, top_k=2, d_ff_expert=64,
            dense_residual=model.moe.dense_residual,
            n_groups=2, capacity_factor=model.moe.capacity_factor)
    if model.ssm.enabled:
        small["ssm"] = SSMConfig(d_state=16, expand=2, head_dim=16,
                                 conv_kernel=4, chunk_size=32)
    if model.family == Family.HYBRID:
        small["shared_attn_every"] = 1
    if model.family == Family.ENCDEC:
        small["n_encoder_layers"] = 2
        small["encoder_seq"] = 32
    if model.family == Family.VLM:
        small["n_patches"] = 8
        small["d_patch"] = 32
    if model.swa_window:
        small["swa_window"] = 32
    small.update(overrides)
    return dataclasses.replace(model, name=model.name + "-smoke", **small)
