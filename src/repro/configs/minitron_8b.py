"""minitron-8b — assigned architecture config (public literature).

Selectable via ``--arch minitron-8b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family=Family.DENSE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    mlp_variant="relu2",
    rope_theta=10_000.0,
    source="[arXiv:2407.14679; hf] pruned nemotron",
)
