from repro.configs.base import (  # noqa: F401
    Completeness,
    Family,
    ModelConfig,
    MoEConfig,
    OptimizerConfig,
    RunConfig,
    SHAPES,
    SLOConfig,
    ShapeConfig,
    ShardingOverrides,
    SSMConfig,
    reduced,
)
from repro.configs.registry import (  # noqa: F401
    ARCHS,
    all_cells,
    cell_applicable,
    get_arch,
    get_shape,
    get_smoke_arch,
    make_run,
)
