"""mixtral-8x22b — assigned architecture config (public literature).

Selectable via ``--arch mixtral-8x22b``.
"""
from __future__ import annotations

from repro.configs.base import Family, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family=Family.MOE,
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,            # expert hidden size
    vocab=32768,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384, n_groups=4),
    swa_window=4096,       # assigned: SWA
    rope_theta=1_000_000.0,
    source="[arXiv:2401.04088; hf]",
)
