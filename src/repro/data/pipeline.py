"""Host-side data pipeline with backlog-aware routing (paper §III-A applied
to the training input path).

N producer shards feed M host ingest queues (bounded = credits). The router
is pluggable with the same strategies as the stream engine: static
round-robin (baseline) vs backlog-based shuffle (divert batches away from
congested hosts — e.g. hosts sharing a slow NIC or doing checkpoint uploads).
`next_global_batch` assembles a deterministic global batch every step
regardless of routing, so training math is unchanged; only the *wait time*
(straggler stall) differs — which is what the benchmark measures.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.backlog_shuffle import BacklogShuffle, ChannelState, Rebalance
from repro.core.chaos import ChaosEngine


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_hosts: int = 8
    queue_cap: int = 16            # batches per host queue (credits)
    batch_tokens: int = 4096
    strategy: str = "backlog"      # "rebalance" | "backlog"
    backlog_threshold: int = 12
    seed: int = 0


class TokenSource:
    """Deterministic synthetic token shards (stable across restarts given the
    same cursor — the data-cursor is part of the checkpoint region state)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.cursor = 0

    def batch_at(self, cursor: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, cursor))
        tokens = rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                              dtype=np.int32)
        return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:],
                "cursor": cursor}

    def next(self) -> dict[str, np.ndarray]:
        out = self.batch_at(self.cursor)
        self.cursor += 1
        return out


class BackpressurePipeline:
    def __init__(self, source: TokenSource, cfg: PipelineConfig,
                 chaos: ChaosEngine | None = None):
        self.source = source
        self.cfg = cfg
        self.chaos = chaos or ChaosEngine()
        self.queues = [deque() for _ in range(cfg.n_hosts)]
        self.state = ChannelState.fresh(cfg.n_hosts, cfg.queue_cap)
        self.router = (BacklogShuffle(cfg.backlog_threshold)
                       if cfg.strategy == "backlog" else Rebalance())
        self.stalls = 0
        self.produced = 0
        # per-host drain rate (batches per pump) — stragglers drain slower
        self.drain = np.array([1.0 if not self.chaos.is_straggler(h)
                               else 1.0 / self.chaos.spec.straggler_factor
                               for h in range(cfg.n_hosts)])
        self._drain_credit = np.zeros(cfg.n_hosts)

    def pump(self, n_batches: int = 1) -> None:
        """Produce n batches and route them to host queues (backlog
        refreshed between batches — the fine-grained reference path)."""
        lens = np.array([len(q) for q in self.queues], np.int64)
        for _ in range(n_batches):
            self.state.backlog = lens
            host = int(self.router.assign(1, self.state)[0])
            if lens[host] >= self.cfg.queue_cap:
                # credit exhausted → stall (backpressure to the producer)
                self.stalls += 1
                host = int(np.argmin(lens))
            self.queues[host].append(self.source.next())
            lens[host] += 1
            self.produced += 1

    def pump_chunked(self, n_batches: int) -> None:
        """Vectorized pump: route the whole chunk in ONE `router.assign`
        call against the chunk-start backlog (the quota logic inside
        BacklogShuffle was built for exactly this), then apply credit caps.
        Overflowing batches stall and divert to the least-backlogged hosts.
        Semantically this is the coarse-credit variant of `pump` — backlog
        feedback is per chunk, not per batch."""
        lens = np.array([len(q) for q in self.queues], np.int64)
        self.state.backlog = lens
        hosts = np.asarray(self.router.assign(n_batches, self.state))
        for host in hosts:
            if lens[host] >= self.cfg.queue_cap:
                self.stalls += 1
                host = int(np.argmin(lens))
            self.queues[host].append(self.source.next())
            lens[host] += 1
        self.produced += n_batches

    def drain_step(self) -> list[dict]:
        """Each host consumes according to its drain rate (stragglers lag)."""
        out = []
        self._drain_credit += self.drain
        for h, q in enumerate(self.queues):
            while self._drain_credit[h] >= 1.0 and q:
                out.append(q.popleft())
                self._drain_credit[h] -= 1.0
        return out

    def backlog_cv(self) -> float:
        lens = np.array([len(q) for q in self.queues], float)
        mu = lens.mean()
        return float(lens.std() / mu) if mu > 0 else 0.0
