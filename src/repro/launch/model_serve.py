"""End-to-end model-serving driver: prefill a batch of requests, decode with
the KV/SSM caches, with State-LazyLoad restore and hybrid replication wired
in. (Moved from `repro.launch.serve`, which now hosts the sweep service.)

Example:
  PYTHONPATH=src python -m repro.launch.model_serve --arch mixtral-8x22b \
      --smoke --requests 8 --prompt-len 64 --decode-steps 32 --lazyload
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfg_base
from repro.configs import registry
from repro.ckpt.storage import SimHDFS
from repro.core import regions as R
from repro.core.chaos import ChaosEngine
from repro.core.clock import WallClock
from repro.core.lazyload import LazyRestorer
from repro.core.region_checkpoint import RegionCheckpointer
from repro.dist.sharding import NO_SHARDING
from repro.models import build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b",
                    choices=sorted(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--lazyload", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro-serve-ckpt")
    ap.add_argument("--out", default="results/serve_run.json")
    args = ap.parse_args()

    cfg = registry.get_smoke_arch(args.arch)
    model = build(cfg)
    s_max = args.prompt_len + args.decode_steps
    print(f"serving {cfg.name}: {args.requests} requests, "
          f"prompt {args.prompt_len}, {args.decode_steps} new tokens")

    # --- weights come from a (possibly lazily restored) checkpoint --------
    params = model.init(jax.random.PRNGKey(0))
    clock = WallClock()
    store = SimHDFS(pathlib.Path(args.ckpt_dir), clock=clock,
                    chaos=ChaosEngine(), bandwidth_bps=5e7)
    regions = R.partition_regions(model.param_specs(), 6)
    ckpt = RegionCheckpointer(store, f"serve-{cfg.name}", regions, clock=clock)
    ckpt.save(0, params)

    t0 = time.perf_counter()
    if args.lazyload:
        lazy = LazyRestorer(ckpt, params, gamma="full",
                            priority=list(range(len(regions))), max_workers=3)
        lazy.wait_region(0)
        ttfr = time.perf_counter() - t0
        weights = jax.tree.map(jnp.asarray, lazy.wait_all())
    else:
        restored, _ = ckpt.restore(params, gamma="full")
        weights = jax.tree.map(jnp.asarray, restored)
        ttfr = time.perf_counter() - t0
    restore_s = time.perf_counter() - t0

    # --- batched prefill + decode -----------------------------------------
    shape = cfg_base.ShapeConfig("serve", args.prompt_len, args.requests,
                                 "prefill")
    batch = model.demo_batch(shape, jax.random.PRNGKey(1))
    moe_opts = {"mode": "weakhash", "rescue": False}

    t0 = time.perf_counter()
    prefill = jax.jit(lambda p, b: model.prefill(p, b, NO_SHARDING,
                                                 s_max=s_max,
                                                 moe_opts=moe_opts))
    logits, cache, pos = prefill(weights, batch)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    decode = jax.jit(lambda p, c, t, i: model.decode_step(
        p, c, t, i, NO_SHARDING, moe_opts=moe_opts))
    tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.perf_counter()
    out_tokens = [tokens]
    for i in range(args.decode_steps):
        logits, cache = decode(weights, cache, tokens,
                               jnp.asarray(pos + i, jnp.int32))
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tokens)
    jax.block_until_ready(tokens)
    decode_s = time.perf_counter() - t0

    summary = {
        "arch": cfg.name,
        "restore_s": round(restore_s, 3),
        "time_to_first_region_s": round(ttfr, 3),
        "lazyload": args.lazyload,
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_tok_s": round(args.requests * args.decode_steps / decode_s, 1),
        "generated": int(jnp.stack(out_tokens).size),
    }
    pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(args.out).write_text(json.dumps(summary, indent=1))
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
