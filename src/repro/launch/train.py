"""End-to-end resilient training driver.

Wires the full StreamShield stack around the jax train loop: SLO-derived
policy → hybrid replication (region checkpoints / hot standby) → backlog-
aware data pipeline → DS2 autoscaler observation → chaos drills. Runs on CPU
with reduced configs (``--arch <id> --smoke``) and on the production mesh
unchanged (the dry-run proves the lowering).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 50 --preset 100m
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfg_base
from repro.configs import registry
from repro.ckpt.storage import FallbackStorage, ObjectStoreSim, SimHDFS
from repro.core import regions as R
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.clock import WallClock
from repro.core.region_checkpoint import RegionCheckpointer
from repro.core.replication import ReplicationManager
from repro.core.slo import policy_for
from repro.data.pipeline import BackpressurePipeline, PipelineConfig, TokenSource
from repro.dist.sharding import NO_SHARDING
from repro.models import build
from repro.train import train_loop
from repro.train.optimizer import make_optimizer


def preset_100m() -> cfg_base.ModelConfig:
    """A ~100M-param dense config for the end-to-end driver."""
    return cfg_base.ModelConfig(
        name="driver-100m", family=cfg_base.Family.DENSE, n_layers=10,
        d_model=640, n_heads=10, n_kv_heads=10, head_dim=64, d_ff=2560,
        vocab=32_768, source="driver preset")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=sorted(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--preset", choices=["100m"], default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--gamma", choices=["full", "partial"], default="full")
    ap.add_argument("--tau-max", type=float, default=30.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    ap.add_argument("--chaos-storage", type=float, default=0.05,
                    help="slow-upload probability (Fig 8 conditions)")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="step at which to simulate a worker loss + restore")
    ap.add_argument("--out", default="results/train_run.json")
    args = ap.parse_args()

    if args.preset == "100m":
        model_cfg = preset_100m()
    elif args.smoke:
        model_cfg = registry.get_smoke_arch(args.arch)
    else:
        model_cfg = registry.get_arch(args.arch)

    shape = cfg_base.ShapeConfig("driver", args.seq, args.batch, "train")
    slo = cfg_base.SLOConfig(cfg_base.Completeness(args.gamma),
                             lambda_max_s=60.0, tau_max_s=args.tau_max)
    policy = policy_for(slo)
    run = cfg_base.RunConfig(model=model_cfg, shape=shape, slo=slo)

    model = build(model_cfg)
    print(f"model={model_cfg.name} params="
          f"{model_cfg.param_count() / 1e6:.1f}M policy={policy.description}")

    params = model.init(jax.random.PRNGKey(run.seed))
    step_fn = train_loop.make_train_step(model, run, NO_SHARDING)
    step_jit = jax.jit(step_fn)
    opt_state = step_fn.optimizer.init(params)

    # --- resiliency substrate -------------------------------------------
    chaos = ChaosEngine(ChaosSpec(seed=1,
                                  storage_slow_prob=args.chaos_storage,
                                  storage_slow_factor=10.0))
    clock = WallClock()
    hdfs = SimHDFS(pathlib.Path(args.ckpt_dir) / "hdfs", clock=clock,
                   chaos=chaos, bandwidth_bps=2e9)
    store = FallbackStorage(
        hdfs, ObjectStoreSim(pathlib.Path(args.ckpt_dir) / "s3", clock=clock),
        clock=clock)
    # regions cover the full training state: params + optimizer slots
    state_specs = {"params": model.param_specs(),
                   "opt": step_fn.optimizer.state_specs(model.param_specs())}
    regions = R.partition_regions(state_specs, 4)
    ckpt = RegionCheckpointer(store, f"train-{model_cfg.name}", regions,
                              mode=policy.ckpt_mode, clock=clock)
    mgr = ReplicationManager(policy, ckpt, clock=clock)

    src = TokenSource(model_cfg.vocab, args.batch, args.seq, seed=7)
    pipe = BackpressurePipeline(src, PipelineConfig(n_hosts=4,
                                                    strategy="backlog"),
                                chaos=chaos)

    # --- train loop --------------------------------------------------------
    losses, times = [], []
    state = {"params": params, "opt": opt_state}
    for step in range(args.steps):
        pipe.pump(2)
        batches = pipe.drain_step()
        if not batches:
            continue
        b = batches[0]
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "targets": jnp.asarray(b["targets"])}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_jit(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        losses.append(float(metrics["loss"]))
        times.append(dt)
        mgr.on_step(step, {"params": params, "opt": opt_state})
        if step == args.inject_failure_at:
            print(f"[chaos] simulated worker loss at step {step}")
            restored, oc = mgr.on_failure(step,
                                          {"params": params, "opt": opt_state})
            params, opt_state = restored["params"], restored["opt"]
            params = jax.tree.map(jnp.asarray, params)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
            print(f"[chaos] recovered via {oc.mode} in {oc.downtime_s:.2f}s "
                  f"(lost_steps={oc.lost_steps})")
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={losses[-1]:.4f} "
                  f"{dt:.2f}s/step ckpts={len(ckpt.reports)}")

    summary = {
        "model": model_cfg.name,
        "params_m": model_cfg.param_count() / 1e6,
        "steps": len(losses),
        "loss_first": losses[0] if losses else None,
        "loss_last": losses[-1] if losses else None,
        "s_per_step": float(np.mean(times[1:])) if len(times) > 1 else None,
        "ckpt_stats": ckpt.success_rate(),
        "pipeline_stalls": pipe.stalls,
        "policy": policy.description,
    }
    pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(args.out).write_text(json.dumps(summary, indent=1))
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
