"""Sequential dry-run sweep over all (arch × shape × mesh) cells.

Each cell runs in a fresh subprocess (fresh XLA, RAM released); existing JSONs
are skipped so the sweep is resumable. Three passes per the §Dry-run protocol:

  1. single-pod, layer-scans UNROLLED  → accurate flops / collective bytes
  2. single-pod train+prefill, ROLLED (tag "mem") → realistic loop-buffer
     memory_analysis (unrolled HLO loses buffer reuse)
  3. multi-pod, ROLLED → proves the "pod" axis shards every cell

Usage: PYTHONPATH=src python -m repro.launch.sweep [--only-pass N] [--dry]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

from repro.configs import registry
from repro.configs.base import SHAPES

OUT = pathlib.Path("results/dryrun")

# cheap-to-expensive compile order (by layer count × width)
ARCH_ORDER = [
    "stablelm-1.6b", "mamba2-1.3b", "whisper-medium", "zamba2-2.7b",
    "phi-3-vision-4.2b", "minitron-8b", "stablelm-12b", "arctic-480b",
    "mixtral-8x22b", "granite-34b",
]
SHAPE_ORDER = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def jobs(only_pass: int | None = None):
    out = []
    for pass_id, (multi, unroll, tag, kinds) in enumerate([
        (False, True, "", ("train", "prefill", "decode")),
        (False, False, "mem", ("train", "prefill")),
        (True, False, "", ("train", "prefill", "decode")),
    ], start=1):
        if only_pass and pass_id != only_pass:
            continue
        for arch in ARCH_ORDER:
            model = registry.get_arch(arch)
            for shape_name in SHAPE_ORDER:
                shape = SHAPES[shape_name]
                if shape.kind not in kinds:
                    continue
                ok, _ = registry.cell_applicable(model, shape)
                if not ok:
                    continue
                out.append((pass_id, arch, shape_name, multi, unroll, tag))
    return out


def job_path(arch, shape, multi, tag):
    mesh_tag = "multi" if multi else "single"
    suffix = f"-{tag}" if tag else ""
    return OUT / f"{arch}--{shape}--{mesh_tag}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only-pass", type=int, default=None)
    ap.add_argument("--timeout", type=int, default=3000)
    ap.add_argument("--dry", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    todo = jobs(args.only_pass)
    print(f"{len(todo)} cells")
    for i, (pass_id, arch, shape, multi, unroll, tag) in enumerate(todo):
        path = job_path(arch, shape, multi, tag)
        if path.exists() and not args.force:
            try:
                rec = json.loads(path.read_text())
                if rec.get("status") == "ok" and rec.get("unroll") == unroll:
                    print(f"[{i+1}/{len(todo)}] skip {path.name}")
                    continue
            except Exception:
                pass
        if args.dry:
            print(f"[{i+1}/{len(todo)}] would run {path.name}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape]
        if multi:
            cmd.append("--multi-pod")
        if not unroll:
            cmd.append("--no-unroll")
        if tag:
            cmd += ["--tag", tag]
        t0 = time.time()
        print(f"[{i+1}/{len(todo)}] pass{pass_id} {path.name} ...", flush=True)
        try:
            r = subprocess.run(cmd, timeout=args.timeout,
                               capture_output=True, text=True)
            first = (r.stdout or r.stderr).strip().splitlines()
            print(f"    {first[0] if first else '??'} "
                  f"[{time.time()-t0:.0f}s rc={r.returncode}]", flush=True)
        except subprocess.TimeoutExpired:
            print(f"    TIMEOUT after {args.timeout}s", flush=True)
            path.write_text(json.dumps(
                {"arch": arch, "shape": shape,
                 "mesh": "multi_pod" if multi else "single_pod",
                 "status": "error", "error": f"compile timeout {args.timeout}s",
                 "unroll": unroll}))


if __name__ == "__main__":
    main()
