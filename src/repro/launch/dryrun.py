import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede any jax-importing module: jax locks device count on init.

"""Multi-pod dry-run: lower + compile every (architecture × input-shape × mesh)
cell against the production mesh with ShapeDtypeStruct stand-ins (no
allocation), then record memory_analysis / cost_analysis / collective traffic
for EXPERIMENTS.md §Dry-run and the §Roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch arctic-480b \
      --shape train_4k [--multi-pod] [--out results/dryrun] [--opt ...]
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro.configs import base as cfg_base
from repro.configs import registry
from repro.dist import sharding as shd
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.train import optimizer as opt_lib
from repro.train import train_loop


def _batch_shardings(model, shape, ctx):
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    pspecs = model.input_pspecs(shape, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def lower_cell(run: cfg_base.RunConfig, *, unroll: bool = True):
    """Build mesh + model + step for one cell and lower it. Returns
    (lowered, info dict). ``unroll`` expands layer scans so cost_analysis
    counts every layer (XLA does not scale while-loop bodies by trip count).
    """
    mesh = make_production_mesh(multi_pod=run.multi_pod)
    serving = run.shape.kind != "train"
    rules = dict(shd.DEFAULT_RULES)
    slot_axes_rule = train_loop.expert_slot_axes(run)
    rules["expert"] = slot_axes_rule
    if serving:
        # Serving profile: no FSDP (per-step param all-gathers would dominate
        # decode); params TP-sharded over "model", replicated over data axes;
        # replicated experts spread over the whole pod (global EP).
        rules["embed"] = ()
    ctx = shd.ShardingCtx(mesh=mesh, rules=rules,
                          sequence_parallel=run.sharding.sequence_parallel,
                          unroll=unroll)
    slot_axes = train_loop.expert_slot_axes(run)
    n_slots = 1
    if run.model.moe.enabled:
        import math
        n_slots = math.prod(mesh.shape[a] for a in slot_axes)
    from repro.models import moe as moe_lib
    replicate = (serving and run.model.moe.enabled
                 and moe_lib.serve_replicate(run.model))
    model = build(run.model, n_slots=n_slots, moe_replicate=replicate)

    abstract_params = model.abstract_params()
    param_sh = model.param_shardings(ctx)
    batch_sds = model.input_specs(run.shape)
    batch_sh = _batch_shardings(model, run.shape, ctx)

    if run.shape.kind == "train":
        step = train_loop.make_train_step(model, run, ctx)
        opt = step.optimizer
        opt_specs = opt.state_specs(model.param_specs())
        abstract_opt = shd.tree_abstract(opt_specs)
        opt_sh = shd.tree_shardings(opt_specs, ctx)
        jf = jax.jit(step,
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        lowered = jf.lower(abstract_params, abstract_opt, batch_sds)
    elif run.shape.kind == "prefill":
        step = train_loop.make_prefill_step(model, run, ctx)
        cache_sh = model.cache_shardings(run.shape.global_batch,
                                         run.shape.seq_len, ctx)
        jf = jax.jit(step, in_shardings=(param_sh, batch_sh),
                     out_shardings=(None, cache_sh, None))
        lowered = jf.lower(abstract_params, batch_sds)
    else:  # decode
        step = train_loop.make_decode_step(model, run, ctx)
        cache_sh = model.cache_shardings(run.shape.global_batch,
                                         run.shape.seq_len, ctx)
        jf = jax.jit(step,
                     in_shardings=(param_sh, cache_sh, batch_sh["tokens"],
                                   batch_sh["pos"]),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,))
        lowered = jf.lower(abstract_params, batch_sds["cache"],
                           batch_sds["tokens"], batch_sds["pos"])
    return lowered, {"mesh": dict(mesh.shape), "n_slots": n_slots}


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             overrides: dict | None = None, unroll: bool = True,
             layers: int | None = None) -> dict:
    """Lower + compile one cell; returns the JSON-able result record.

    layers: override n_layers (the roofline's linear-in-L extrapolation for
    heavy unrolled cells: full = rolled + (L-1)·(small_unrolled - rolled)/(l-1)).
    """
    t0 = time.time()
    run = registry.make_run(arch, shape, multi_pod=multi_pod)
    if layers:
        model = dataclasses.replace(run.model, n_layers=layers)
        if model.family.value == "hybrid":
            model = dataclasses.replace(
                model, shared_attn_every=min(model.shared_attn_every, layers))
        run = dataclasses.replace(run, model=model)
        rec_layers = layers
    if overrides:
        run = dataclasses.replace(
            run, sharding=dataclasses.replace(run.sharding, **overrides))
    ok, why = registry.cell_applicable(run.model, run.shape)
    rec: dict = {"arch": arch, "shape": shape,
                 "mesh": "multi_pod" if multi_pod else "single_pod",
                 "sharding": dataclasses.asdict(run.sharding),
                 "optimizer": run.optimizer.name}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    rec["unroll"] = unroll
    if layers:
        rec["layers_override"] = layers
    try:
        lowered, info = lower_cell(run, unroll=unroll)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        txt = compiled.as_text()
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=hlo_stats.memory_stats(compiled),
            cost=hlo_stats.cost_stats(compiled),
            collectives=hlo_stats.collective_stats(txt),
            devices=int(len(jax.devices())),
            **info,
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(cfg_base.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--global-a2a", action="store_true",
                    help="baseline: expert dispatch over (data×model)")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep layer scans rolled (fast compile; costs count "
                         "the loop body once — used for the multi-pod pass)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--cap-floor", type=int, default=None)
    ap.add_argument("--grad-bf16", action="store_true")
    ap.add_argument("--exact-attn", action="store_true")
    ap.add_argument("--remat-dots", action="store_true")
    args = ap.parse_args()

    overrides: dict = {}
    if args.cap_floor is not None:
        overrides["moe_capacity_floor"] = args.cap_floor
    if args.grad_bf16:
        overrides["grad_reduce_bf16"] = True
    if args.exact_attn:
        overrides["exact_attn_blocks"] = True
    if args.remat_dots:
        overrides["remat"] = "dots"
    if args.remat:
        overrides["remat"] = args.remat
    if args.no_seq_parallel:
        overrides["sequence_parallel"] = False
    if args.global_a2a:
        overrides["grouped_a2a"] = False

    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   overrides=overrides or None, unroll=not args.no_unroll,
                   layers=args.layers)
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    mesh_tag = "multi" if args.multi_pod else "single"
    suffix = f"-{args.tag}" if args.tag else ""
    path = out / f"{args.arch}--{args.shape}--{mesh_tag}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))

    if rec["status"] == "ok":
        mem = rec["memory"]
        cost = rec["cost"]
        coll = rec["collectives"]["total"]
        print(f"OK {args.arch} {args.shape} {mesh_tag}{suffix} "
              f"compile={rec['compile_s']}s "
              f"peak={mem['peak_bytes']/2**30:.2f}GiB/dev "
              f"flops={cost['flops']/1e12:.3f}T/dev "
              f"hbm={cost['bytes_accessed']/2**30:.2f}GiB/dev "
              f"ici={coll['ici_bytes']/2**20:.1f}MiB/dev")
        # paper deliverable: prove it fits + expose FLOPs/bytes
        print(json.dumps({"memory_analysis": mem, "cost_analysis": cost},
                         indent=1))
    else:
        print(f"{rec['status'].upper()} {args.arch} {args.shape}: "
              f"{rec.get('reason') or rec.get('error')}")
        if rec["status"] == "error":
            print(rec["trace"])
            raise SystemExit(1)


if __name__ == "__main__":
    main()
