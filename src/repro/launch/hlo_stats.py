"""Parse collective traffic out of compiled (post-SPMD) HLO text.

``cost_analysis()`` does not report collective bytes, so the roofline's
collective term is derived here: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op is matched, its per-device
shape and replica-group size extracted, and effective ICI bytes-per-device
computed with standard ring-cost factors:

  all-gather        out_bytes · (g-1)/g
  reduce-scatter    out_bytes · (g-1)
  all-reduce        out_bytes · 2(g-1)/g
  all-to-all        out_bytes · (g-1)/g
  collective-permute out_bytes · 1
"""
from __future__ import annotations

import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = math.prod(int(d) for d in dims.split(",") if d) if dims else 1
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


_FACTORS = {
    "all-gather": lambda b, g: b * (g - 1) / g,
    "reduce-scatter": lambda b, g: b * (g - 1),
    "all-reduce": lambda b, g: b * 2 * (g - 1) / g,
    "all-to-all": lambda b, g: b * (g - 1) / g,
    "collective-permute": lambda b, g: b,
}


def collective_stats(hlo_text: str) -> dict:
    """Returns {op: {"count": int, "bytes": raw output bytes,
    "ici_bytes": effective per-device bytes}} plus a "total" entry."""
    stats: dict = defaultdict(lambda: {"count": 0, "bytes": 0.0, "ici_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, op, is_start = m.group(1), m.group(2), m.group(3)
        if "-done" in line.split("=")[1][:40]:
            continue
        b = _shape_bytes(shape_str)
        g = _group_size(line)
        if g <= 1:
            # degenerate group → no traffic
            stats[op]["count"] += 1
            continue
        stats[op]["count"] += 1
        stats[op]["bytes"] += b
        stats[op]["ici_bytes"] += _FACTORS[op](b, g)
    total = {"count": sum(v["count"] for v in stats.values()),
             "bytes": sum(v["bytes"] for v in stats.values()),
             "ici_bytes": sum(v["ici_bytes"] for v in stats.values())}
    out = {k: dict(v) for k, v in stats.items()}
    out["total"] = total
    return out


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        "peak_bytes": int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                          + ma.temp_size_in_bytes
                          - int(getattr(ma, "alias_size_in_bytes", 0))),
    }


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: [dict] per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


_HLO_ANY_OP_RE = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w-]*)\(")


def hlo_op_counts(hlo_text: str) -> dict:
    """Instruction-name histogram of compiled HLO text — the op-mix
    companion to `cost_stats` (how many fusions / gathers / scatters /
    reduces the lowering actually emitted). Keys are HLO opcode names,
    values are instruction counts."""
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _HLO_ANY_OP_RE.search(line)
        if m:
            counts[m.group(1)] += 1
    return dict(counts)
