"""Roofline analysis over the dry-run records (§Roofline deliverable).

Per (arch × shape) on the single-pod mesh, three terms in seconds-per-step
per chip (TPU v5e constants):

  compute    = HLO_FLOPs / 197e12        (bf16 peak per chip)
  memory     = HLO_bytes / 819e9         (HBM bandwidth)
  collective = effective ICI bytes / 50e9 (per-link bandwidth)

plus MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE + attention term), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, and the
roofline fraction = ideal-compute-time / bound-time.

SSM/hybrid cells get an analytic correction: the SSD chunk loop remains a
rolled `lax.scan` in the dry-run (XLA counts the body once), so its
(nc−1)/nc remainder is added back analytically (see DESIGN.md).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs import SHAPES, registry
from repro.configs.base import Family, ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link
CHIPS = 256              # single pod
VMEM_BYTES = 16 * 2**20  # usable VMEM per core (conservative)


def choose_block_rows(row_bytes: float, fixed_bytes: float = 0.0,
                      budget: int = VMEM_BYTES,
                      max_rows: int = 256) -> int:
    """Largest pow2 block row count whose VMEM working set
    (``fixed_bytes + rows × row_bytes``) fits the budget — the generic
    grid-block sizer for hand-fused kernels (`repro.kernels.tick_phase`
    sizes its seed-axis blocks with it; the grid-invariant row tables
    are the fixed residents)."""
    rows = max_rows
    while rows > 1 and fixed_bytes + rows * row_bytes > budget:
        rows //= 2
    return rows


def kernel_roofline(flops: float, hbm_bytes: float) -> dict:
    """Roofline terms of one compiled function / kernel launch from its
    HLO cost analysis (`launch.hlo_stats.cost_stats`): compute and
    memory seconds under the chip constants above, arithmetic
    intensity vs the machine balance, and which side bounds it. Used by
    benchmarks/bench_compile.py and bench_tick_kernel.py to report
    per-lowering FLOP/byte alongside jaxpr eqn counts."""
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    return {"flops": flops, "hbm_bytes": hbm_bytes,
            "compute_s": compute_s, "memory_s": memory_s,
            "intensity_flops_per_byte": flops / max(hbm_bytes, 1.0),
            "machine_balance": PEAK_FLOPS / HBM_BW,
            "bound": "compute" if compute_s >= memory_s else "memory"}


def attn_flops(cfg: ModelConfig, shape: ShapeConfig, *, fwd_mult: float) -> float:
    """Global attention matmul FLOPs (QK^T + PV) for the step."""
    if cfg.family == Family.SSM:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    d_attn = cfg.n_heads * cfg.head_dim
    if cfg.family == Family.HYBRID:
        layers = cfg.n_layers // cfg.shared_attn_every
        d_attn = cfg.n_heads * cfg.head_dim
    elif cfg.family == Family.ENCDEC:
        layers = cfg.n_layers  # decoder self-attn; enc/cross added below
    else:
        layers = cfg.n_layers
    if shape.kind == "decode":
        ctx = min(cfg.swa_window or S, S)
        fl = 4 * layers * B * ctx * d_attn
        if cfg.family == Family.ENCDEC:
            fl += 4 * cfg.n_layers * B * cfg.encoder_seq * d_attn
        return fl * fwd_mult
    ctx_avg = S / 2 if not cfg.swa_window else min(cfg.swa_window, S / 2)
    fl = 4 * layers * B * S * ctx_avg * d_attn
    if cfg.family == Family.ENCDEC:
        enc = cfg.encoder_seq
        fl += 4 * cfg.n_encoder_layers * B * enc * enc * d_attn  # bidir enc
        fl += 4 * cfg.n_layers * B * S * enc * d_attn            # cross
    return fl * fwd_mult


def ssd_correction(cfg: ModelConfig, shape: ShapeConfig,
                   fwd_mult: float) -> float:
    """Analytic SSD chunk-loop FLOPs missing from the rolled scan: add back
    (nc-1)/nc of the total (the HLO counted one chunk)."""
    if cfg.family not in (Family.SSM, Family.HYBRID) or shape.kind == "decode":
        return 0.0
    s = cfg.ssm
    B, S = shape.global_batch, shape.seq_len
    Q = min(s.chunk_size, S)
    nc = max(S // Q, 1)
    if nc <= 1:
        return 0.0
    H = s.n_heads(cfg.d_model)
    P, N = s.head_dim, s.d_state
    per_chunk = B * (2 * Q * Q * N          # C·Bᵀ
                     + 2 * Q * Q * H * P    # w @ x
                     + 4 * Q * H * P * N)   # state update + y_inter
    total = per_chunk * nc * cfg.n_layers
    return total * (nc - 1) / nc * fwd_mult


def model_flops(cfg: ModelConfig, shape: ShapeConfig, remat: str) -> float:
    """Ideal useful FLOPs for the step (global)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        base = 6 * n_active * shape.tokens_per_step
        return base + attn_flops(cfg, shape, fwd_mult=3.0)
    mult = 1.0
    base = 2 * n_active * shape.tokens_per_step
    return base + attn_flops(cfg, shape, fwd_mult=mult)


def load(dirpath: pathlib.Path):
    recs = {}
    for f in dirpath.glob("*.json"):
        rec = json.loads(f.read_text())
        parts = f.stem.split("--")          # arch--shape--mesh[-tag]
        mesh_kind = ("multi_pod" if parts[2].startswith("multi")
                     else "single_pod")
        tag = parts[2].split("-", 1)[1] if "-" in parts[2] else "main"
        recs[(rec["arch"], rec["shape"], mesh_kind, tag)] = rec
    return recs


def analyse(recs, arch: str, shape_name: str):
    cfg = registry.get_arch(arch)
    shape = SHAPES[shape_name]
    main = recs.get((arch, shape_name, "single_pod", "main"))
    mem_rec = recs.get((arch, shape_name, "single_pod", "mem")) or main
    extrapolated = False
    if main is None or main.get("status") != "ok":
        # heavy-cell fallback: reconstruct full-depth unrolled costs from the
        # l8 anchor + the rolled record — layer costs are exactly linear in L
        # (identical scanned layers): full = rolled + (L-1)·(l8 − rolled)/(l−1)
        l8 = recs.get((arch, shape_name, "single_pod", "l8"))
        rolled = recs.get((arch, shape_name, "single_pod", "mem"))
        if not (l8 and rolled and l8.get("status") == "ok"
                and rolled.get("status") == "ok"):
            return main and {"status": main.get("status", "missing"),
                             "reason": main.get("reason",
                                                main.get("error", ""))}
        lsmall = l8.get("layers_override", 8)
        L = cfg.n_layers

        def extra(get):
            body = (get(l8) - get(rolled)) / max(lsmall - 1, 1)
            return get(rolled) + (L - 1) * max(body, 0.0)

        main = {
            "status": "ok",
            "cost": {
                "flops": extra(lambda r: r["cost"]["flops"]),
                "bytes_accessed": extra(lambda r: r["cost"]["bytes_accessed"]),
            },
            "collectives": {"total": {"ici_bytes": extra(
                lambda r: r["collectives"]["total"]["ici_bytes"])}},
            "memory": rolled["memory"],
            "compile_s": l8.get("compile_s"),
        }
        mem_rec = rolled
        extrapolated = True

    flops_dev = main["cost"]["flops"]
    fwd_mult = 3.0 if shape.kind == "train" else 1.0
    flops_dev += ssd_correction(cfg, shape, fwd_mult) / CHIPS
    bytes_dev = main["cost"]["bytes_accessed"]
    ici_dev = main["collectives"]["total"]["ici_bytes"]

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = ici_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape, "block") / CHIPS
    ratio = mf / flops_dev if flops_dev else 0.0
    bound = max(terms.values())
    frac = (mf / PEAK_FLOPS) / bound if bound else 0.0
    peak_gib = (mem_rec["memory"]["peak_bytes"]
                if mem_rec.get("status") == "ok" else
                main["memory"]["peak_bytes"]) / 2 ** 30
    return {
        "status": "ok", "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops_dev": mf, "hlo_flops_dev": flops_dev,
        "useful_ratio": ratio, "roofline_fraction": frac,
        "peak_gib": peak_gib,
        "fits_16g": peak_gib <= 16.0,
        "compile_s": main.get("compile_s"),
        "extrapolated": extrapolated,
    }


def table(dirpath: str = "results/dryrun") -> str:
    recs = load(pathlib.Path(dirpath))
    lines = ["| arch | shape | compute s | memory s | coll s | dominant | "
             "MODEL/HLO | roofline frac | peak GiB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for arch, shape_name, runs, why in registry.all_cells():
        if not runs:
            lines.append(f"| {arch} | {shape_name} | — | — | — | skipped | "
                         f"— | — | — |")
            continue
        a = analyse(recs, arch, shape_name)
        if not a or a.get("status") != "ok":
            lines.append(f"| {arch} | {shape_name} | ? | ? | ? | "
                         f"{(a or {}).get('status')} | ? | ? | ? |")
            continue
        lines.append(
            f"| {arch} | {shape_name} | {a['compute_s']:.4f} | "
            f"{a['memory_s']:.4f} | {a['collective_s']:.4f} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.3f} | {a['peak_gib']:.1f}"
            f"{'' if a['fits_16g'] else ' ⚠'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.json:
        recs = load(pathlib.Path(args.dir))
        out = {}
        for arch, shape_name, runs, _ in registry.all_cells():
            if runs:
                out[f"{arch}/{shape_name}"] = analyse(recs, arch, shape_name)
        print(json.dumps(out, indent=1, default=str))
    else:
        print(table(args.dir))


if __name__ == "__main__":
    main()
