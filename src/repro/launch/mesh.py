"""Production mesh construction.

A pod is 256 chips arranged ``(16, 16) ("data", "model")``; the multi-pod
deployment is 2 pods = 512 chips ``(2, 16, 16) ("pod", "data", "model")``.
The ``"model"`` axis is ICI-contiguous — Group-Rescale (DESIGN.md §1) confines
expert all-to-alls to it.

These are FUNCTIONS, not module constants: importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests / elastic reconfiguration."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)  # older jax: axes are Auto by default


def make_host_mesh(n_data: int = 1, n_model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests use forced host devices)."""
    n = len(jax.devices())
    assert n_data * n_model <= n, (n_data, n_model, n)
    return make_mesh((n_data, n_model), ("data", "model"))
