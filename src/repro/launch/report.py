"""Splice generated §Dry-run / §Roofline tables into EXPERIMENTS.md.

Usage: PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import registry
from repro.launch import roofline


def dryrun_table(dirpath="results/dryrun") -> str:
    recs = roofline.load(pathlib.Path(dirpath))
    lines = ["| arch | shape | single-pod | compile s | peak GiB/chip "
             "(rolled) | multi-pod (2×16×16) |",
             "|---|---|---|---|---|---|"]
    n_ok_single = n_ok_multi = n_skip = 0
    for arch, shape_name, runs, why in registry.all_cells():
        if not runs:
            n_skip += 1
            lines.append(f"| {arch} | {shape_name} | skipped — "
                         f"{why.split(';')[0].split('—')[0].strip()} | — | — "
                         f"| skipped |")
            continue
        single = recs.get((arch, shape_name, "single_pod", "main"))
        mem = recs.get((arch, shape_name, "single_pod", "mem")) or single
        multi = recs.get((arch, shape_name, "multi_pod", "main"))

        def st(r):
            if r is None:
                return "—"
            return "✓" if r.get("status") == "ok" else r.get("status")

        s_ok = st(single)
        if s_ok != "✓":  # extrapolated cells still count via anchors
            a = roofline.analyse(recs, arch, shape_name)
            if a and a.get("status") == "ok":
                s_ok = "✓ (l8 extrapolation)"
        if s_ok.startswith("✓"):
            n_ok_single += 1
        if st(multi) == "✓":
            n_ok_multi += 1
        peak = "?"
        if mem and mem.get("status") == "ok":
            peak = f"{mem['memory']['peak_bytes'] / 2**30:.1f}"
            if mem["memory"]["peak_bytes"] > 16 * 2**30:
                peak += " ⚠"
        comp = single.get("compile_s") if single and single.get(
            "status") == "ok" else None
        lines.append(f"| {arch} | {shape_name} | {s_ok} | "
                     f"{comp if comp else '—'} | {peak} | {st(multi)} |")
    lines.append("")
    lines.append(f"**{n_ok_single} single-pod cells compiled, {n_ok_multi} "
                 f"multi-pod cells compiled, {n_skip} principled skips "
                 f"(= 40 cells accounted).**")
    return "\n".join(lines)


def splice(md_path="EXPERIMENTS.md"):
    p = pathlib.Path(md_path)
    text = p.read_text()
    dr = dryrun_table()
    rf = roofline.table()
    text = _replace_block(text, "DRYRUN-TABLE", dr)
    text = _replace_block(text, "ROOFLINE-TABLE", rf)
    p.write_text(text)
    print(f"updated {md_path}")


def _replace_block(text: str, marker: str, content: str) -> str:
    """Replace everything between the marker line and the next section
    heading with the freshly generated content (idempotent)."""
    tag = f"<!-- {marker} -->"
    i = text.index(tag)
    j = text.find("\n## ", i)
    if j == -1:
        j = len(text)
    return text[:i] + tag + "\n\n" + content + "\n" + text[j:]


if __name__ == "__main__":
    splice()
