"""Sweep-as-a-service: a thread-backed job queue over the chaos-sweep
drivers with incremental per-chunk results and a shared jit cache.

StreamShield's deployment pipeline treats resiliency sweeps as a release
gate — "can this config ship?" — which needs a *service*, not a batch
script: requests arrive concurrently, callers want the first partial
surface now (not the full cube later), and same-shaped requests must
not re-trace. `SweepService` provides exactly that on top of
`streams.chaos_sweep`:

* **Job queue.** `submit(kind, graph, seeds, **kwargs)` enqueues one of
  the five request kinds — ``"sweep"``, ``"sweep_configs"``,
  ``"replication_tradeoff"``, ``"deployment_drill"`` (the flagship
  release-gate cube), ``"traffic_sweep"`` — and returns a `SweepJob`
  immediately; a small worker pool drains the queue.
* **Incremental results.** Each request executes in seed-chunked device
  passes (`seed_chunk=`, driver-side `on_chunk=`): as every ``(C,
  S_chunk)`` chunk lands it is published to the job's replayable chunk
  buffer, so ANY number of subscribers can iterate `SweepJob.chunks()`
  — late subscribers replay the history first (the Ray buffered-
  publisher idiom), early ones block until the next chunk or the final
  result. Time-to-first-result is one chunk's wall time instead of the
  whole cube's; the concatenated final cube is bit-identical to the
  monolithic call (`jax_engine` chunking contract).
* **Shared trace cache.** Compiled traces key on (plan digest / bucket
  signature, grid shape, phase mode) — never on request identity — so
  concurrent requests over same-shaped plans share ONE process-global
  jit cache (`jax_engine._cache_get` under one lock). Per-request
  hit/miss counters land in `SweepJob.stats` via the thread-local
  `scoped_cache_stats`; one-trace-across-requests is pinned by
  tests/test_sweep_service.py.
* **Pipelined prep.** Host-side timeline prep for chunk k+1 overlaps
  device compute for chunk k (`jax_engine.run_chunks`' double-buffered
  lane); the measured split rides each job's ``prep_s`` / ``device_s``.
* **Pallas downgrade.** ``phase_mode="pallas"`` + ``devices=`` has no
  sharded lowering; instead of surfacing the boundary error the service
  routes the request to a single-device *chunked* plan up front and
  records the downgrade reason in ``stats["downgrade"]``.

Example::

    with SweepService(workers=2) as svc:
        job = svc.submit("deployment_drill", graph, range(64),
                         seed_chunk=8, base_spec=spec, duration_s=120.0,
                         policies=policies, failover=fo)
        for chunk in job.chunks():       # partial (C, S_chunk) surfaces
            gate.update(chunk.recovery_surface)
        cube = job.result()              # == the monolithic cube

CLI smoke (one drill request, incremental chunk lines)::

    PYTHONPATH=src python -m repro.launch.serve --seeds 16 --chunk 4

The old model-serving driver that seeded this module lives on as
`repro.launch.model_serve`.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time

from repro.streams import chaos_sweep
from repro.streams.jax_engine import scoped_cache_stats, trace_cache_stats

#: request kind → driver. Every driver has signature
#: ``fn(graph, seeds, *, ..., seed_chunk=None, on_chunk=None)`` (the
#: cube wrappers forward both through ``**sweep_kw``).
KINDS = {
    "sweep": chaos_sweep.sweep,
    "sweep_configs": chaos_sweep.sweep_configs,
    "replication_tradeoff": chaos_sweep.replication_tradeoff,
    "deployment_drill": chaos_sweep.deployment_drill,
    "traffic_sweep": chaos_sweep.traffic_sweep,
}


@dataclasses.dataclass
class SweepRequest:
    """One queued sweep request: a driver kind, its (graph, seeds)
    positional payload and the driver kwargs. ``seed_chunk`` selects the
    chunked pipeline (None = monolithic single pass — still one
    published "chunk"); ``label`` names the job in stats."""
    kind: str
    graph: object
    seeds: object
    kwargs: dict = dataclasses.field(default_factory=dict)
    seed_chunk: int | None = None
    label: str | None = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown request kind {self.kind!r} "
                             f"(one of {sorted(KINDS)})")


class SweepJob:
    """Handle for a submitted request: a replayable chunk buffer plus
    the final result.

    `chunks()` yields `chaos_sweep.SweepChunk`s in landing order and is
    safe for ANY number of concurrent consumers — each iterator keeps
    its own cursor over the buffered history (late subscribers replay
    from chunk 0) and blocks on the job's condition for chunks that
    have not landed yet. `result()` blocks until the driver returns and
    re-raises the driver's exception on failure. `stats` carries the
    service-side telemetry: state, queue/run/total wall, time-to-first-
    result, prep/device split, per-request trace-cache hits/misses and
    any pallas downgrade reason."""

    def __init__(self, job_id: int, request: SweepRequest):
        self.id = job_id
        self.request = request
        self._cond = threading.Condition()
        self._chunks: list = []
        self._done = False
        self._error: BaseException | None = None
        self._result = None
        self.stats: dict = {"state": "queued", "chunks": 0,
                            "ttfr_s": None, "wall_s": None,
                            "downgrade": None}

    # -- producer side (service worker) --------------------------------
    def _publish(self, chunk) -> None:
        with self._cond:
            self._chunks.append(chunk)
            self.stats["chunks"] = len(self._chunks)
            self._cond.notify_all()

    def _finish(self, result=None, error: BaseException | None = None
                ) -> None:
        with self._cond:
            self._result = result
            self._error = error
            self._done = True
            self.stats["state"] = "failed" if error else "done"
            self._cond.notify_all()

    # -- consumer side --------------------------------------------------
    def chunks(self, timeout: float | None = None):
        """Yield every `SweepChunk` in landing order; returns when the
        job finishes (raises its error if it failed). `timeout` bounds
        each wait, raising TimeoutError on expiry."""
        i = 0
        while True:
            with self._cond:
                while i >= len(self._chunks) and not self._done:
                    if not self._cond.wait(timeout):
                        raise TimeoutError(
                            f"job {self.id}: no chunk within {timeout}s")
                if i < len(self._chunks):
                    chunk = self._chunks[i]
                    i += 1
                else:
                    if self._error is not None:
                        raise self._error
                    return
            yield chunk

    def first_chunk(self, timeout: float | None = None):
        """Block until the first chunk lands and return it."""
        return next(iter(self.chunks(timeout)))

    def result(self, timeout: float | None = None):
        """Block until the driver returns; the full sweep/cube result
        (bit-identical to the monolithic call)."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout):
                raise TimeoutError(f"job {self.id}: not done "
                                   f"within {timeout}s")
            if self._error is not None:
                raise self._error
            return self._result

    def done(self) -> bool:
        with self._cond:
            return self._done


def _grid_of(result):
    """The underlying `SweepResult`/`ConfigSweepResult` of any driver's
    return (cube wrappers carry it as ``.grid``)."""
    return getattr(result, "grid", result)


class SweepService:
    """Thread-backed sweep service: a FIFO request queue drained by
    `workers` daemon threads, every job chunk-published as it executes.

    All workers share the process-global jit caches, so concurrent
    same-shaped requests compile once and hit thereafter; per-request
    attribution comes from `scoped_cache_stats` (thread-local counters
    around each driver call). Use as a context manager or call
    `shutdown()`; `stats()` aggregates job telemetry plus the
    process-wide `trace_cache_stats()`."""

    def __init__(self, workers: int = 2,
                 default_seed_chunk: int | None = None):
        self.default_seed_chunk = default_seed_chunk
        self._queue: queue.Queue = queue.Queue()
        self._jobs: dict[int, SweepJob] = {}
        self._ids = itertools.count()
        self._lock = threading.Lock()
        self._workers = [threading.Thread(target=self._worker,
                                          name=f"sweep-worker-{i}",
                                          daemon=True)
                         for i in range(max(1, int(workers)))]
        for t in self._workers:
            t.start()

    # -- submission ------------------------------------------------------
    def submit(self, kind: str, graph, seeds, *,
               seed_chunk: int | None = None, label: str | None = None,
               **kwargs) -> SweepJob:
        """Enqueue a sweep request and return its `SweepJob` handle
        immediately. `kind` is one of `KINDS`; `kwargs` go to the
        driver verbatim (``base_spec``, ``duration_s``, ``policies``,
        ...). ``seed_chunk`` falls back to the service default."""
        return self.submit_request(SweepRequest(
            kind, graph, seeds, kwargs=kwargs,
            seed_chunk=(seed_chunk if seed_chunk is not None
                        else self.default_seed_chunk),
            label=label))

    def submit_request(self, request: SweepRequest) -> SweepJob:
        with self._lock:
            job = SweepJob(next(self._ids), request)
            self._jobs[job.id] = job
        job.stats["submitted_s"] = time.perf_counter()
        self._queue.put(job)
        return job

    def job(self, job_id: int) -> SweepJob:
        with self._lock:
            return self._jobs[job_id]

    def jobs(self) -> list[SweepJob]:
        with self._lock:
            return list(self._jobs.values())

    # -- execution -------------------------------------------------------
    def _worker(self) -> None:
        while True:
            job = self._queue.get()
            if job is None:
                return
            try:
                self._run(job)
            finally:
                self._queue.task_done()

    def _run(self, job: SweepJob) -> None:
        req = job.request
        kwargs = dict(req.kwargs)
        seed_chunk = req.seed_chunk
        seeds = list(req.seeds)

        # pallas + devices has no sharded lowering: downgrade to a
        # single-device chunked plan up front (instead of surfacing
        # `jax_engine._check_pallas_devices`'s boundary error) and
        # record why — the chunking bounds per-pass memory, which is
        # what devices= was presumably for
        if (kwargs.get("devices") is not None
                and kwargs.get("phase_mode") == "pallas"):
            if seed_chunk is None:
                seed_chunk = max(1, min(16, len(seeds)))
            job.stats["downgrade"] = (
                f"pallas phase mode has no devices= sharding (native "
                f"seed batching); rerouted devices="
                f"{kwargs['devices']!r} -> single-device chunked plan "
                f"(seed_chunk={seed_chunk})")
            kwargs["devices"] = None

        job.stats["state"] = "running"
        t0 = time.perf_counter()
        job.stats["queued_s"] = t0 - job.stats.pop("submitted_s", t0)

        def publish(chunk):
            if job.stats["ttfr_s"] is None:
                job.stats["ttfr_s"] = time.perf_counter() - t0
            job._publish(chunk)

        try:
            # sweep_configs is the one driver with a second positional
            # (the config grid) — accept it as the `configs` kwarg
            args = (req.graph, seeds)
            if req.kind == "sweep_configs":
                args = (req.graph, kwargs.pop("configs"), seeds)
            with scoped_cache_stats() as counts:
                result = KINDS[req.kind](*args, seed_chunk=seed_chunk,
                                         on_chunk=publish, **kwargs)
        except BaseException as exc:              # noqa: BLE001
            job.stats["wall_s"] = time.perf_counter() - t0
            job._finish(error=exc)
            return
        wall = time.perf_counter() - t0
        grid = _grid_of(result)
        job.stats.update(
            wall_s=wall,
            ttfr_s=(job.stats["ttfr_s"] if job.stats["ttfr_s"]
                    is not None else wall),
            prep_s=getattr(grid, "prep_s", 0.0),
            device_s=getattr(grid, "device_s", 0.0),
            cache_hits=counts["hits"], cache_misses=counts["misses"])
        job._finish(result=result)

    # -- lifecycle / telemetry ------------------------------------------
    def shutdown(self, wait: bool = True) -> None:
        for _ in self._workers:
            self._queue.put(None)
        if wait:
            for t in self._workers:
                t.join()

    def stats(self) -> dict:
        """Service-level telemetry: per-job stats plus the process-wide
        trace-cache counters every request shares."""
        jobs = self.jobs()
        done = [j for j in jobs if j.stats["state"] == "done"]
        return {
            "jobs": {j.id: dict(j.stats, kind=j.request.kind,
                                label=j.request.label) for j in jobs},
            "completed": len(done),
            "trace_cache": trace_cache_stats(),
            "cache_hits": sum(j.stats.get("cache_hits", 0)
                              for j in done),
            "cache_misses": sum(j.stats.get("cache_misses", 0)
                                for j in done),
        }

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


def main() -> None:
    """CLI smoke: one deployment-drill request through the service,
    chunk lines printed as they land."""
    import argparse
    import json
    import math

    from repro.core.chaos import ChaosSpec
    from repro.streams import nexmark
    from repro.streams.engine import FailoverConfig, UpgradeConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--phase-mode", default="auto")
    args = ap.parse_args()

    g = nexmark.q2(parallelism=4)
    spec = ChaosSpec(host_kill_prob_per_s=0.002,
                     zk_down=((20.0, 24.0),))
    fo = FailoverConfig(mode="single_task", detect_s=1.0,
                        single_restart_s=2.0)
    policies = {"hot": UpgradeConfig(t_upgrade_s=10.0,
                                     wave_stagger_s=1.0)}
    with SweepService(workers=2) as svc:
        job = svc.submit("deployment_drill", g, range(args.seeds),
                         seed_chunk=args.chunk, base_spec=spec,
                         duration_s=args.duration, policies=policies,
                         canary_fracs=(0.25, 0.5),
                         rollback_thresholds=(math.inf, 200.0),
                         failover=fo, n_hosts=8,
                         phase_mode=args.phase_mode,
                         label="cli-drill")
        for chunk in job.chunks():
            print(f"chunk {chunk.index}: seeds "
                  f"[{chunk.seed_lo},{chunk.seed_hi}) "
                  f"prep={chunk.prep_s:.3f}s "
                  f"device={chunk.device_s:.3f}s", flush=True)
        cube = job.result()
        print(json.dumps({"rollback_frac":
                          cube.rollback_frac.mean(axis=-1).tolist(),
                          **{k: v for k, v in job.stats.items()
                             if isinstance(v, (int, float, str))
                             or v is None}},
                         indent=1, default=str))


if __name__ == "__main__":
    main()
