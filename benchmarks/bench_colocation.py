"""Multi-job mega-arena throughput: scenarios/s of ONE packed co-located
sweep (K jobs, shared host pool, one device call per shard) vs running
the K jobs' sweeps separately on the same seed batch.

The packed arena shares one trace, one chaos-timeline prep pass per seed
(instead of K) and one device dispatch per tick horizon, so co-located
fleet screening beats sequential per-job sweeps well beyond 2x per core.
Emits the usual CSV rows through benchmarks/run.py and writes
``results/bench_colocation.json`` (scenarios/s, per-job p95 recovery,
vs-separate speedup) for the perf trajectory. Quick mode
(REPRO_BENCH_QUICK=1) shrinks the batch and horizon to a few seconds.
"""
from __future__ import annotations

import json
import pathlib
import time

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep
from repro.streams.engine import FailoverConfig, pack_arena

BASE_SPEC = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
FAILOVER = FailoverConfig(mode="region", region_restart_s=20.0)


def _job_mix():
    return [nexmark.q2(parallelism=8, partitioner="weakhash", n_groups=4,
                       service_rate=1.1e5),
            nexmark.q12(parallelism=8, service_rate=2.4e5),
            nexmark.ds(parallelism=6),
            nexmark.ss(parallelism=4)]


def run():
    quick = quick_mode()
    n_seeds = 16 if quick else 256
    duration = 60.0 if quick else 120.0
    graphs = _job_mix()
    arena = pack_arena(graphs, "shared", n_hosts=8)

    def packed():
        return sweep(arena, range(n_seeds), base_spec=BASE_SPEC,
                     duration_s=duration, failover=FAILOVER)

    def separate():
        t0 = time.perf_counter()
        res = [sweep(g, range(n_seeds), base_spec=BASE_SPEC,
                     duration_s=duration, n_hosts=8, failover=FAILOVER)
               for g in graphs]
        return res, time.perf_counter() - t0

    # cold (trace + compile) then warm for both strategies
    t0 = time.perf_counter()
    packed()
    packed_cold = time.perf_counter() - t0
    _, sep_cold = separate()
    t0 = time.perf_counter()
    res = packed()
    packed_warm = time.perf_counter() - t0
    sep_res, sep_warm = separate()

    k = arena.n_jobs
    job_scen_s = k * n_seeds / packed_warm
    speedup = sep_warm / packed_warm
    per_job = {
        name: {
            "recovery_p95_s": jr.aggregate()["recovery_p95_s"],
            "slo_violation_frac_p95":
                jr.aggregate()["slo_violation_frac_p95"],
        } for name, jr in res.job_results.items()}
    rows = [(f"colocation/{k}jobs/{n_seeds}seeds",
             1e6 / job_scen_s,
             f"job_scenarios_s={job_scen_s:.0f};"
             f"speedup_vs_separate={speedup:.2f}x;"
             f"cold_speedup={sep_cold / packed_cold:.2f}x;"
             f"p95_recovery_worst="
             f"{max(v['recovery_p95_s'] for v in per_job.values()):.1f}s")]
    if quick:   # quick smoke must not overwrite the tracked record
        return rows
    record = {
        "n_jobs": k, "n_seeds": n_seeds, "duration_s": duration,
        "n_ticks": res.n_ticks, "n_hosts": arena.n_hosts,
        "n_tasks": arena.plan.n_tasks,
        "packed_cold_wall_s": packed_cold, "packed_warm_wall_s": packed_warm,
        "separate_cold_wall_s": sep_cold, "separate_warm_wall_s": sep_warm,
        "scenarios_per_s": job_scen_s,
        "separate_scenarios_per_s": k * n_seeds / sep_warm,
        "speedup_vs_separate": speedup,
        "cold_speedup_vs_separate": sep_cold / packed_cold,
        "per_job": per_job,
        "separate_recovery_p95_s": {
            g.name: r.aggregate()["recovery_p95_s"]
            for g, r in zip(graphs, sep_res)},
        "fleet_aggregate": res.aggregate(),
    }
    out = pathlib.Path("results")
    out.mkdir(parents=True, exist_ok=True)
    (out / "bench_colocation.json").write_text(json.dumps(record, indent=1))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
