"""Micro benchmarking (paper §V-C): per-kernel timings. On this CPU-only
container we time the jnp oracle (jit'd) at reduced shapes and the Pallas
kernel in interpret mode (correctness-path cost); real-TPU wall numbers come
from deploying the same entry points on hardware."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    # one warmup call; block on the whole result pytree (the old version
    # called fn twice during warmup and only synced tuple results' first leaf)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []

    # flash attention oracle (jit)
    from repro.kernels.api import flash_attention
    B, S, H, KV, D = 1, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                impl="ref"))
    us = _time(f, q, k, v)
    flops = 4 * B * S * S * H * D / 2  # causal
    rows.append((f"kernels/flash_attention/{S}x{H}x{D}", us,
                 f"gflops_s={flops / us / 1e3:.1f}"))

    # decode attention oracle
    from repro.kernels.api import decode_attention
    S2 = 32_768
    q1 = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.bfloat16)
    kc = jnp.asarray(rng.normal(size=(B, S2, KV, D)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(size=(B, S2, KV, D)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: decode_attention(q, k, v, kv_valid_len=S2,
                                                 impl="ref"))
    us = _time(f, q1, kc, vc)
    rows.append((f"kernels/decode_attention/kv{S2}", us,
                 f"gb_s={(kc.nbytes + vc.nbytes) / us / 1e3:.1f}"))

    # ssd scan oracle
    from repro.kernels.api import ssd_scan
    B3, S3, H3, P3, N3 = 1, 2048, 16, 64, 64
    x = jnp.asarray(rng.normal(size=(B3, S3, H3, P3)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B3, S3, H3)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2, (H3,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B3, S3, N3)), jnp.bfloat16)
    Cm = jnp.asarray(rng.normal(size=(B3, S3, N3)), jnp.bfloat16)
    f = jax.jit(lambda *a: ssd_scan(*a, chunk=256, impl="ref")[0])
    us = _time(f, x, dt, A, Bm, Cm)
    rows.append((f"kernels/ssd_scan/{S3}x{H3}", us,
                 f"mtok_s={B3 * S3 / us:.2f}"))

    # weakhash route oracle
    from repro.kernels.api import weakhash_route
    T, E = 8192, 128
    logits = jnp.asarray(rng.normal(size=(T, E)), jnp.float32)
    keys = jnp.asarray(rng.integers(0, 1 << 20, T), jnp.int32)
    f = jax.jit(lambda l, kk: weakhash_route(
        l, top_k=2, capacity=2 * T // E, n_groups=16, mode="weakhash",
        token_keys=kk, impl="ref").expert_idx)
    us = _time(f, logits, keys)
    rows.append((f"kernels/weakhash_route/{T}x{E}", us,
                 f"mtok_s={T / us:.2f}"))

    # pallas interpret-mode validation cost (small shape)
    from repro.kernels.flash_attention import kernel as FK
    qs = jnp.asarray(rng.normal(size=(1, 128, 4, 64)), jnp.float32)
    ks = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    vs = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    t0 = time.perf_counter()
    FK.flash_attention(qs, ks, vs, interpret=True, block_q=64, block_k=64)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("kernels/flash_attention/interpret128", us, "validation"))
    return rows
