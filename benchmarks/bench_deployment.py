"""Deployment-drill cube (release-gate drills): rollback rate / SLO
violation / lost work over upgrade-policy × canary-fraction ×
rollback-threshold, produced by ONE `sweep_configs` device call
(`streams.chaos_sweep.deployment_drill`), plus the hot-vs-cold per-wave
restart latency the drill lowers from the `core.hotupdate` deploy model.

Emits the usual CSV rows through benchmarks/run.py and writes
``results/bench_deployment.json`` for the perf trajectory. Quick mode
(REPRO_BENCH_QUICK=1) shrinks the cube and horizon so the module runs in
a few seconds on CPU — and, per the harness contract, skips the JSON
write.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
import time

import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.chaos import ChaosSpec, timeline_build_count
from repro.core.hotupdate import deploy_downtime
from repro.core.startup import StartupConfig
from repro.streams import nexmark
from repro.streams.chaos_sweep import deployment_drill
from repro.streams.engine import FailoverConfig, UpgradeConfig

# ambient kills plus a ZK/HDFS leader-loss overlap mid-drill: the cube
# measures canary rollback behaviour *under* coordinator-gate chaos, not
# on a quiet fleet
BASE_SPEC = ChaosSpec(host_kill_prob_per_s=0.001,
                      zk_down=((30.0, 34.0),), hdfs_down=((32.0, 38.0),))
FO = FailoverConfig(mode="single_task", detect_s=1.0, single_restart_s=2.0)


def _policies() -> dict[str, UpgradeConfig]:
    # the induced regression every gated cell must catch: canary
    # selectivity 1.5 > the fleet's 1.2 sink headroom, so upgraded
    # slices overload their sinks until the controller rolls them back
    drill = UpgradeConfig(t_upgrade_s=10.0, wave_stagger_s=1.0,
                          canary_sel_scale=1.5, rollback_window_s=4.0)
    return {
        "hot": dataclasses.replace(drill, hot=True),
        "cold": dataclasses.replace(drill, hot=False),
        "cold+accel": dataclasses.replace(drill, hot=False,
                                          startup=StartupConfig()),
    }


def run():
    quick = quick_mode()
    n_seeds = 4 if quick else 32
    duration = 60.0 if quick else 120.0
    fleet = nexmark.drill_fleet(n_jobs=2 if quick else 8, queue_cap=1e9)
    policies = _policies()
    fracs = (0.5,) if quick else (0.25, 0.5, 1.0)
    thresholds = (math.inf, 100.0)

    c0 = timeline_build_count()
    cold_t0 = time.perf_counter()
    deployment_drill(fleet, range(n_seeds), base_spec=BASE_SPEC,
                     duration_s=duration, policies=policies,
                     canary_fracs=fracs, rollback_thresholds=thresholds,
                     failover=FO, n_hosts=16)
    cold_wall = time.perf_counter() - cold_t0
    cube = deployment_drill(fleet, range(n_seeds), base_spec=BASE_SPEC,
                            duration_s=duration, policies=policies,
                            canary_fracs=fracs,
                            rollback_thresholds=thresholds,
                            failover=FO, n_hosts=16)
    builds = timeline_build_count() - c0

    n_cells = cube.rollback_t.size

    # headline: the per-wave restart latency the drill pays per slice —
    # hot redeploys reuse the compile cache and skip the cold first-step
    # mitigations, cold redeploys pay the full §III startup pipeline
    # (accelerated grid point = best StartupConfig over the policy grid)
    hot_s = deploy_downtime(None, hot=True)
    grid_s = [deploy_downtime(sc, hot=False)
              for sc in StartupConfig.policy_grid()]
    cold_s, accel_s = max(grid_s), min(grid_s)
    rb = np.asarray(cube.rollback_t)
    gated = rb[:, :, 1]                      # finite-threshold slot
    t_rb = {pol: float(gated[p][np.isfinite(gated[p])].mean())
            for p, pol in enumerate(cube.policies)}
    rows = [(f"deployment/drill_fleet/{n_cells}cells",
             1e6 * cube.grid.wall_s / n_cells,
             f"cells={n_cells};cells_s={n_cells / cube.grid.wall_s:.0f};"
             f"hot_deploy_s={hot_s:.1f};cold_deploy_s={cold_s:.1f};"
             f"accel_cold_s={accel_s:.1f};"
             f"hot_rollback_s={t_rb['hot']:.1f};"
             f"cold_rollback_s={t_rb['cold']:.1f};"
             f"timeline_builds={builds}")]
    if not quick:   # quick smoke must not overwrite the tracked record
        record = {
            "n_seeds": n_seeds, "duration_s": duration,
            "policies": list(cube.policies),
            "canary_fracs": list(cube.canary_fracs),
            "rollback_thresholds": [
                None if math.isinf(t) else t
                for t in cube.rollback_thresholds],
            "cold_wall_s": cold_wall, "warm_wall_s": cube.grid.wall_s,
            "cells_per_s": n_cells / cube.grid.wall_s,
            "timeline_builds": builds,
            "hot_deploy_s": hot_s, "cold_deploy_s": cold_s,
            "accel_cold_deploy_s": accel_s,
            "rollback_t_mean": {pol: t_rb[pol] for pol in cube.policies},
            "rollback_frac": np.asarray(cube.rollback_frac).tolist(),
            "slo_mean": np.asarray(cube.slo).mean(-1).tolist(),
            "lost_mean": np.asarray(cube.lost).mean(-1).tolist(),
        }
        out = pathlib.Path("results")
        out.mkdir(parents=True, exist_ok=True)
        (out / "bench_deployment.json").write_text(
            json.dumps(record, indent=1))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
