"""Hybrid-replication tradeoff cube (paper §IV-A): recovery time / SLO
violation / lost work over replication-mode × checkpoint-interval ×
storage-brownout-severity, produced by ONE `sweep_configs` device call
(`streams.chaos_sweep.replication_tradeoff`).

Emits the usual CSV rows through benchmarks/run.py and writes
``results/bench_replication.json`` for the perf trajectory. Quick mode
(REPRO_BENCH_QUICK=1) shrinks the cube and horizon so the module runs in
a few seconds on CPU — and, per the harness contract, skips the JSON
write.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time

import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.chaos import ChaosSpec, timeline_build_count
from repro.core.replication import TimingModel
from repro.streams import nexmark
from repro.streams.chaos_sweep import replication_tradeoff
from repro.streams.engine import FailoverConfig

# the deterministic region burst guarantees every seed sees ≥1 recovery
# (otherwise empty-scenario recovery times are inf and the cube means
# degenerate); the Poisson kill stream adds seed-to-seed variance on top
BASE_SPEC = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.1,
                      burst_at=((20.0, 0),))
STATE_BYTES = 8 << 30            # 8 GiB of keyed window state per job
TIMING = TimingModel()


def _failovers() -> dict[str, FailoverConfig]:
    # single_task passive restore (γ=partial: records routed to the dead
    # task are dropped → lost work) vs region passive with lazy-load
    # ready stagger vs hot standby. The 5s region redeploy keeps that
    # row's downtime dominated by the brownout-inflated restore +
    # ckpt-age replay terms the cube sweeps.
    return {
        "hot_standby": FailoverConfig.from_replication(
            TIMING, mode="hot_standby"),
        "passive": FailoverConfig.from_replication(
            TIMING, mode="single_task", state_bytes=STATE_BYTES),
        "passive_lazy": dataclasses.replace(
            FailoverConfig.from_replication(TIMING, mode="region",
                                            state_bytes=STATE_BYTES),
            region_restart_s=5.0, lazyload_stagger_s=1.0),
    }


def run():
    quick = quick_mode()
    n_seeds = 8 if quick else 64
    duration = 60.0 if quick else 180.0
    graph = nexmark.q12(parallelism=4 if quick else 8)
    failovers = _failovers()
    intervals = (None, 10.0) if quick else (None, 10.0, 30.0, 60.0)
    # tent ramps centered on the burst (t=20) so the severity axis
    # actually inflates the restores the burst triggers
    bros = ((), ((5.0, 35.0, 2.0),)) if quick else \
        ((), ((5.0, 35.0, 2.0),), ((5.0, 35.0, 4.0),),
         ((5.0, 35.0, 8.0),))

    c0 = timeline_build_count()
    cold_t0 = time.perf_counter()
    replication_tradeoff(graph, range(n_seeds), base_spec=BASE_SPEC,
                         duration_s=duration, failovers=failovers,
                         ckpt_intervals=intervals, brownouts=bros,
                         n_hosts=8)
    cold_wall = time.perf_counter() - cold_t0
    cube = replication_tradeoff(graph, range(n_seeds), base_spec=BASE_SPEC,
                                duration_s=duration, failovers=failovers,
                                ckpt_intervals=intervals, brownouts=bros,
                                n_hosts=8)
    builds = timeline_build_count() - c0

    n_cells = cube.recovery.size

    def _fmean(a):
        a = np.asarray(a, float)
        f = np.isfinite(a)
        return float(a[f].mean()) if f.any() else float("inf")

    # headline: both tradeoff axes at the harshest brownout — recovery
    # time (hot vs best passive ckpt interval) AND lost work (hot drains
    # its retained backlog and loses nothing; passive restores drop the
    # in-flight queues, so its lost-work column is the price of the
    # cheaper drain)
    hot = _fmean(cube.recovery[0, :, -1])
    passive_best = min(_fmean(cube.recovery[1, iv, -1])
                       for iv in range(len(cube.ckpt_intervals)))
    hot_lost = float(np.asarray(cube.lost)[0, :, -1].mean())
    passive_lost = float(np.asarray(cube.lost)[1, :, -1].mean())
    rows = [(f"replication/q12/{n_cells}cells",
             1e6 * cube.grid.wall_s / n_cells,
             f"cells={n_cells};cells_s={n_cells / cube.grid.wall_s:.0f};"
             f"hot_recovery_s={hot:.2f};passive_best_s={passive_best:.2f};"
             f"hot_lost={hot_lost:.0f};passive_lost={passive_lost:.0f};"
             f"timeline_builds={builds}")]
    if not quick:   # quick smoke must not overwrite the tracked record
        record = {
            "n_seeds": n_seeds, "duration_s": duration,
            "modes": cube.modes,
            "ckpt_intervals": [iv for iv in cube.ckpt_intervals],
            "brownout_peaks": cube.brownout_peaks,
            "cold_wall_s": cold_wall, "warm_wall_s": cube.grid.wall_s,
            "cells_per_s": n_cells / cube.grid.wall_s,
            "timeline_builds": builds,
            "hot_recovery_s": hot, "passive_best_s": passive_best,
            "hot_lost": hot_lost, "passive_lost": passive_lost,
            "recovery_mean": np.apply_along_axis(
                _fmean, -1, np.asarray(cube.recovery)).tolist(),
            "slo_mean": np.asarray(cube.slo).mean(-1).tolist(),
            "lost_mean": np.asarray(cube.lost).mean(-1).tolist(),
        }
        out = pathlib.Path("results")
        out.mkdir(parents=True, exist_ok=True)
        (out / "bench_replication.json").write_text(
            json.dumps(record, indent=1))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
