"""Sweep-as-a-service serving benchmark: time-to-first-result vs the
monolithic `sweep_configs` wall on a (C=16, S=64) deployment-drill
cube, sustained request throughput through `SweepService`, the shared
jit-cache hit rate across concurrent requests, and the host-prep /
device-compute overlap efficiency of the double-buffered chunk
pipeline.

Emits the usual CSV rows through benchmarks/run.py and writes
``results/bench_serve.json`` for the perf trajectory. Quick mode
(REPRO_BENCH_QUICK=1) shrinks the cube and horizon so the module runs
in a few seconds on CPU — and, per the harness contract, skips the
JSON write.

The full run enforces the serving acceptance bars loudly: TTFR must be
<= 0.5x the warm monolithic wall, the concurrent requests must share a
compiled trace (cache hits > 0), and the chunked cube must be
bit-identical to the monolithic one.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
import time

import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.chaos import ChaosSpec
from repro.core.startup import StartupConfig
from repro.launch.serve import SweepService
from repro.streams import nexmark
from repro.streams.chaos_sweep import deployment_drill
from repro.streams.engine import FailoverConfig, UpgradeConfig

BASE_SPEC = ChaosSpec(host_kill_prob_per_s=0.001,
                      zk_down=((30.0, 34.0),), hdfs_down=((32.0, 38.0),))
FO = FailoverConfig(mode="single_task", detect_s=1.0, single_restart_s=2.0)

SURFACES = ("recovery", "slo", "lost", "rollback_t")


def _policies(quick: bool) -> dict[str, UpgradeConfig]:
    drill = UpgradeConfig(t_upgrade_s=10.0, wave_stagger_s=1.0,
                          canary_sel_scale=1.5, rollback_window_s=4.0)
    if quick:
        return {"hot": drill}
    # 4 policies x 2 fracs x 2 thresholds = the C=16 acceptance cube
    return {
        "hot": dataclasses.replace(drill, hot=True),
        "hot+fast": dataclasses.replace(drill, hot=True,
                                        rollback_window_s=2.0),
        "cold": dataclasses.replace(drill, hot=False),
        "cold+accel": dataclasses.replace(drill, hot=False,
                                          startup=StartupConfig()),
    }


def run():
    quick = quick_mode()
    n_seeds = 8 if quick else 64
    chunk = 2 if quick else 8
    duration = 60.0 if quick else 90.0
    fleet = nexmark.drill_fleet(n_jobs=2 if quick else 4, queue_cap=1e9)
    kw = dict(base_spec=BASE_SPEC, duration_s=duration,
              policies=_policies(quick), canary_fracs=(0.25, 0.5),
              rollback_thresholds=(math.inf, 100.0), failover=FO,
              n_hosts=16)

    # -- monolithic baseline: cold (compile) then warm ---------------
    cold_t0 = time.perf_counter()
    deployment_drill(fleet, range(n_seeds), **kw)
    cold_wall = time.perf_counter() - cold_t0
    mono = deployment_drill(fleet, range(n_seeds), **kw)
    mono_wall = mono.grid.wall_s
    n_cells = mono.rollback_t.size
    n_cfg = n_cells // n_seeds
    # warm the chunk-sized seed bucket too: chunks pad to their own pow2
    # bucket, a different trace than the full-width monolithic pass —
    # TTFR is a serving-latency bar, measured on warm traces like the
    # monolithic wall it is compared against
    deployment_drill(fleet, range(n_seeds), seed_chunk=chunk, **kw)

    # -- chunked service request: TTFR + overlap + parity ------------
    with SweepService(workers=2, default_seed_chunk=chunk) as svc:
        job = svc.submit("deployment_drill", fleet, range(n_seeds),
                         label="ttfr", **kw)
        cube = job.result(timeout=3600)
        ttfr, chunked_wall = job.stats["ttfr_s"], job.stats["wall_s"]
        prep_s, device_s = job.stats["prep_s"], job.stats["device_s"]

        # -- concurrent pair: one compiled trace, sustained rate -----
        t0 = time.perf_counter()
        pair = [svc.submit("deployment_drill", fleet, range(n_seeds),
                           label=f"pair-{i}", **kw) for i in range(2)]
        for j in pair:
            j.result(timeout=3600)
        pair_wall = time.perf_counter() - t0
        stats = svc.stats()

    parity = all(np.array_equal(getattr(mono, s), getattr(cube, s))
                 for s in SURFACES)
    hits = stats["cache_hits"]
    ttfr_ratio = ttfr / mono_wall
    overlap = device_s / chunked_wall     # device-busy fraction
    req_per_s = len(pair) / pair_wall

    if not parity:
        raise AssertionError("chunked service cube drifted from the "
                             "monolithic deployment_drill")
    if hits < 1:
        raise AssertionError("concurrent requests failed to share a "
                             f"compiled trace (hits={hits})")
    if not quick and ttfr_ratio > 0.5:
        raise AssertionError(f"TTFR {ttfr:.2f}s is {ttfr_ratio:.2f}x "
                             f"the monolithic wall {mono_wall:.2f}s "
                             "(bar: <= 0.5x)")

    rows = [
        (f"serve/ttfr/{n_cfg}x{n_seeds}cube", 1e6 * ttfr,
         f"ttfr_s={ttfr:.2f};mono_wall_s={mono_wall:.2f};"
         f"ttfr_ratio={ttfr_ratio:.2f};chunk={chunk};"
         f"overlap={overlap:.2f};parity={int(parity)}"),
        (f"serve/sustained/{n_cfg}x{n_seeds}cube",
         1e6 * pair_wall / len(pair),
         f"req_s={req_per_s:.2f};cells_s={n_cells * len(pair) / pair_wall:.0f};"
         f"cache_hits={hits};cache_misses={stats['cache_misses']}"),
    ]
    if not quick:   # quick smoke must not overwrite the tracked record
        record = {
            "n_configs": n_cfg, "n_seeds": n_seeds,
            "seed_chunk": chunk, "duration_s": duration,
            "cold_wall_s": cold_wall, "mono_wall_s": mono_wall,
            "chunked_wall_s": chunked_wall,
            "ttfr_s": ttfr, "ttfr_ratio": round(ttfr_ratio, 3),
            "ttfr_speedup": round(mono_wall / ttfr, 2),
            "prep_s": prep_s, "device_s": device_s,
            "overlap_efficiency": round(overlap, 3),
            "concurrent_requests": len(pair),
            "cache_hits": hits, "cache_misses": stats["cache_misses"],
            "shared_trace": hits >= 1,
            "requests_per_s": round(req_per_s, 3),
            "cells_per_s": round(n_cells / mono_wall, 1),
            "parity_ok": parity,
            "note": ("ttfr = first (C, S_chunk) partial surface out of "
                     "SweepService vs the warm one-pass sweep_configs "
                     "wall; overlap = device_s / chunked wall (double-"
                     "buffered host-prep/device-compute pipeline); "
                     "pair = 2 concurrent requests sharing one "
                     "compiled trace via the process-global fn cache"),
        }
        out = pathlib.Path("results")
        out.mkdir(parents=True, exist_ok=True)
        (out / "bench_serve.json").write_text(json.dumps(record, indent=1))
        from benchmarks.bench_sweep_scale import write_summary
        write_summary()
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
