"""Sweep-scale benchmark (ISSUE 5 acceptance): sparse-phase tick
throughput and host-free checkpoint-grid sweeps at 10k-task scale.

Three studies:

* **tick** — warm jitted tick throughput of the 10k-task deep-pipeline
  SS mega-arena (6 phases) and the 10k-task Q12 arena, dense vs compact
  lowering (the acceptance bar: >= 2x under compact).
* **ckpt_grid** — a (C=16 restart×interval configs, S=64 seeds)
  checkpoint-bearing resiliency grid through `sweep_configs`, with the
  host-replay baseline (per-(config, seed) `build_chaos_timeline`)
  timed on the same grid; records the `timeline_build_count` delta,
  which MUST be zero on the batched path.
* **shard** — the same config grid on 1 vs N forced host devices
  (subprocess — the parent jax process is pinned to one device).

Emits CSV rows through benchmarks/run.py and writes
``results/bench_sweep_scale.json`` plus the cross-PR aggregate
``results/bench_summary.json``. Quick mode shrinks the arena/grid and
never overwrites the tracked JSONs.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode

from repro.core.chaos import ChaosSpec, timeline_build_count
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep_configs
from repro.streams.engine import CheckpointConfig, FailoverConfig
from repro.streams.jax_engine import (_Lowered, _enable_x64,
                                      get_cached_run_fns)

SPEC = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
FAILOVER = FailoverConfig(mode="region", region_restart_s=20.0)
RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def tick_study(arena, label: str, n_ticks: int = 64,
               reps: int = 3) -> dict:
    """Warm jitted tick throughput, dense vs compact lowering."""
    rec = {"arena": label, "n_tasks": arena.plan.n_tasks,
           "n_jobs": arena.n_jobs, "n_ticks": n_ticks}
    for mode in ("dense", "compact"):
        low = _Lowered(arena, n_hosts=64, dt=0.5, queue_cap=256.0,
                       failover=FAILOVER, ckpt=None, seed=0,
                       phase_mode=mode)
        rec["n_phases"] = low.tensor.n_phases
        run_fn, _ = get_cached_run_fns(low.desc)
        with _enable_x64():
            state, xs, _ = low.prepare(SPEC, n_ticks)
            t0 = time.perf_counter()
            out = run_fn(low.arrays, state, xs)
            [np.asarray(v) for v in out[1].values()]
            cold = time.perf_counter() - t0
            times = []
            for _ in range(reps):
                state, xs, _ = low.prepare(SPEC, n_ticks)
                t0 = time.perf_counter()
                out = run_fn(low.arrays, state, xs)
                [np.asarray(v) for v in out[1].values()]
                times.append(time.perf_counter() - t0)
        rec[mode] = {"cold_s": round(cold, 3),
                     "warm_s": round(min(times), 4),
                     "ticks_per_s": round(n_ticks / min(times), 1)}
    rec["warm_speedup"] = round(rec["dense"]["warm_s"]
                                / rec["compact"]["warm_s"], 2)
    return rec


def _ckpt_grid(n_restarts: int, n_intervals: int):
    grid = []
    for r in np.linspace(10.0, 60.0, n_restarts):
        for iv in np.linspace(15.0, 60.0, n_intervals):
            grid.append({"failover": FailoverConfig(
                mode="region", region_restart_s=float(r)),
                "ckpt": CheckpointConfig(interval_s=float(iv),
                                         mode="region"),
                "label": f"r={r:.0f} iv={iv:.0f}"})
    return grid


def ckpt_grid_study(n_restarts: int, n_intervals: int, n_seeds: int,
                    duration: float, n_tasks: int,
                    baseline: bool) -> dict:
    """(C, S) checkpoint-interval grid over a packed Q12 arena: the full
    `sweep_configs` wall (compact tick + batched timeline refit) plus a
    direct timeline-PREP comparison — `core.chaos.build_grid_timelines`
    (one draw stream per seed, vectorized per-config refits) vs the
    pre-ISSUE-5 per-(config, seed) `build_chaos_timeline` host replay
    loop on the identical grid."""
    import dataclasses

    from repro.core.chaos import build_grid_timelines
    from repro.streams.engine import per_task_failover

    arena = nexmark.q12_arena(n_tasks=n_tasks, parallelism=8, n_hosts=32)
    grid = _ckpt_grid(n_restarts, n_intervals)
    spec = ChaosSpec(host_kill_prob_per_s=0.002, straggler_frac=0.2,
                     storage_slow_prob=0.2, storage_slow_factor=12)
    b0 = timeline_build_count()
    res = sweep_configs(arena, grid, range(n_seeds), base_spec=spec,
                        duration_s=duration)
    builds = timeline_build_count() - b0
    rec = {"graph": f"q12_arena_{arena.plan.n_tasks}t",
           "C": len(grid), "S": n_seeds,
           "duration_s": duration, "wall_s": round(res.wall_s, 2),
           "scenarios_per_s": round(res.scenarios_per_s, 1),
           "host_timeline_rebuilds": builds,
           "recovery_p50_s": round(float(np.nanmedian(np.where(
               np.isfinite(res.recovery_surface),
               res.recovery_surface, np.nan))), 2)}
    if baseline:
        low = _Lowered(arena, n_hosts=32, dt=0.5, queue_cap=256.0,
                       failover=FAILOVER, ckpt=None, seed=0)
        n_ticks = int(round(duration / 0.5))
        specs = [dataclasses.replace(spec, seed=s)
                 for s in range(n_seeds)]
        rows = []
        for cfg in grid:
            codes, det, rst_s, rst_r = per_task_failover(
                cfg["failover"], low.plan.n_tasks, low.job_of_task)
            ck = cfg["ckpt"]
            rows.append(dict(failover_mode=codes, detect_s=det,
                             region_restart_s=rst_r,
                             single_restart_s=rst_s,
                             ckpt_interval_s=ck.interval_s,
                             ckpt_mode=ck.mode,
                             ckpt_upload_s=ck.upload_s,
                             ckpt_retry=ck.retry_failed_region))
        t0 = time.perf_counter()
        build_grid_timelines(specs, rows, n_ticks=n_ticks, dt=0.5,
                             n_hosts=low.n_hosts,
                             task_host=low.task_host,
                             task_region=low.task_region,
                             regions=low.phys.regions,
                             job_of_task=low.job_of_task)
        rec["grid_prep_s"] = round(time.perf_counter() - t0, 2)
        t0 = time.perf_counter()
        for row in rows:
            for sp in specs:
                low.timeline(sp, n_ticks,
                             fo_codes=row["failover_mode"],
                             detect=row["detect_s"],
                             rst_s=row["single_restart_s"],
                             rst_r=row["region_restart_s"],
                             ckpt=CheckpointConfig(
                                 interval_s=row["ckpt_interval_s"],
                                 mode=row["ckpt_mode"],
                                 upload_s=row["ckpt_upload_s"],
                                 retry_failed_region=row["ckpt_retry"]))
        rec["host_replay_baseline_s"] = round(
            time.perf_counter() - t0, 2)
        rec["timeline_refit_speedup"] = round(
            rec["host_replay_baseline_s"]
            / max(rec["grid_prep_s"], 1e-9), 2)
    return rec


_SHARD_CODE = """
import json
import numpy as np
from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep_configs
from repro.streams.engine import CheckpointConfig, FailoverConfig

grid = [{{"failover": FailoverConfig(mode="region",
                                     region_restart_s=float(r)),
          "ckpt": CheckpointConfig(interval_s=30.0, mode="region")}}
        for r in np.linspace(10.0, 60.0, {nc})]
spec = ChaosSpec(host_kill_prob_per_s=0.002, straggler_frac=0.2,
                 storage_slow_prob=0.2, storage_slow_factor=12)
arena = nexmark.q12_arena(n_tasks={nt}, parallelism=8, n_hosts=32)
kw = dict(base_spec=spec, duration_s={dur}, n_hosts=32)
res = sweep_configs(arena, grid, range({ns}), devices={dev}, **kw)  # warm
res = sweep_configs(arena, grid, range({ns}), devices={dev}, **kw)
print(json.dumps({{"devices": {dev} or 1, "wall_s": round(res.wall_s, 2),
                   "scenarios_per_s": round(res.scenarios_per_s, 1)}}))
"""


def shard_study(n_configs: int, n_seeds: int, duration: float,
                n_tasks: int, n_devices: int = 2) -> dict:
    """1-vs-N-device sharded (C, S) grid over a packed arena
    (subprocess: host devices must be forced before jax initializes;
    N defaults to 2 — pick <= physical cores, host CPU devices share
    the machine)."""
    rec = {"C": n_configs, "S": n_seeds, "n_tasks": n_tasks}
    root = pathlib.Path(__file__).resolve().parent.parent
    for dev in (1, n_devices):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(root / "src")
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{n_devices}")
        code = _SHARD_CODE.format(nc=n_configs, nt=n_tasks, ns=n_seeds,
                                  dur=duration,
                                  dev=(dev if dev > 1 else None))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        if out.returncode != 0:
            rec[f"devices_{dev}"] = {"error": out.stderr[-500:]}
            continue
        rec[f"devices_{dev}"] = json.loads(out.stdout.strip()
                                           .splitlines()[-1])
    one = rec.get("devices_1", {})
    n = rec.get(f"devices_{n_devices}", {})
    if "wall_s" in one and "wall_s" in n:
        rec["shard_speedup"] = round(one["wall_s"] / n["wall_s"], 2)
    return rec


def write_summary() -> dict:
    """Cross-PR perf trajectory: one machine-readable summary pulling
    the headline derived metric out of every tracked results JSON."""
    summary = {}
    for f in sorted(RESULTS.glob("*.json")):
        if f.name == "bench_summary.json":
            continue
        try:
            summary[f.stem] = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError):
            continue
    heads = {}
    c = summary.get("bench_compile", {})
    if c.get("compile"):
        heads["compile_speedup_10k"] = c["compile"][-1].get(
            "compile_speedup")
    s = summary.get("bench_sweep_scale", {})
    for t in s.get("tick", []):
        heads[f"tick_speedup_{t['arena']}"] = t.get("warm_speedup")
    if s.get("ckpt_grid"):
        heads["grid_scenarios_per_s"] = s["ckpt_grid"].get(
            "scenarios_per_s")
        heads["timeline_refit_speedup"] = s["ckpt_grid"].get(
            "timeline_refit_speedup")
    col = summary.get("bench_colocation", {})
    if isinstance(col, dict) and "speedup_vs_separate" in col:
        heads["colocation_speedup"] = col["speedup_vs_separate"]
        heads["colocation_scenarios_per_s"] = col.get("scenarios_per_s")
    tk = summary.get("bench_tick_kernel", {})
    if tk.get("engine"):
        heads["pallas_tick_speedup"] = tk["engine"].get(
            "pallas_vs_compact_speedup")
    if tk.get("mega"):
        heads["mega_job_scenarios_per_pass"] = tk["mega"].get(
            "job_scenarios")
    sv = summary.get("bench_serve", {})
    if isinstance(sv, dict) and "ttfr_speedup" in sv:
        heads["serve_ttfr_speedup"] = sv["ttfr_speedup"]
        heads["serve_overlap_efficiency"] = sv.get("overlap_efficiency")
        heads["serve_requests_per_s"] = sv.get("requests_per_s")
        heads["serve_shared_trace"] = sv.get("shared_trace")
    payload = {"headlines": heads, "sources": sorted(summary)}
    (RESULTS / "bench_summary.json").write_text(
        json.dumps(payload, indent=2))
    return heads


def run():
    quick = quick_mode()
    if quick:
        arenas = [(nexmark.ss_arena(n_tasks=1008, parallelism=8,
                                    n_hosts=32), "ss_1k")]
        grid_dims, n_seeds, duration, grid_tasks = (2, 2), 8, 60.0, 504
    else:
        arenas = [(nexmark.ss_arena(n_tasks=9968, parallelism=8,
                                    n_hosts=64), "ss_10k"),
                  (nexmark.q12_arena(n_tasks=9984, parallelism=8,
                                     n_hosts=64), "q12_10k")]
        grid_dims, n_seeds, duration, grid_tasks = (4, 4), 64, 120.0, 1008

    ticks = []
    for arena, label in arenas:
        rec = tick_study(arena, label)
        ticks.append(rec)
        yield (f"tick_compact_{label}",
               rec["compact"]["warm_s"] * 1e6 / rec["n_ticks"],
               f"{rec['compact']['ticks_per_s']}t/s;"
               f"speedup={rec['warm_speedup']}x")

    grid_rec = ckpt_grid_study(*grid_dims, n_seeds, duration,
                               grid_tasks, baseline=not quick)
    derived = (f"{grid_rec['scenarios_per_s']}scen/s;"
               f"rebuilds={grid_rec['host_timeline_rebuilds']}")
    if "timeline_refit_speedup" in grid_rec:
        derived += f";refit={grid_rec['timeline_refit_speedup']}x"
    yield (f"ckpt_grid_{grid_rec['C']}x{grid_rec['S']}",
           grid_rec["wall_s"] * 1e6, derived)

    shard_rec = None
    if not quick:
        shard_rec = shard_study(4, 64, 120.0, 1008)
        if "shard_speedup" in shard_rec:
            yield ("config_shard_2dev", shard_rec["devices_2"]["wall_s"]
                   * 1e6, f"speedup={shard_rec['shard_speedup']}x")
        RESULTS.mkdir(exist_ok=True)
        payload = {"tick": ticks, "ckpt_grid": grid_rec,
                   "shard": shard_rec,
                   "note": ("tick: warm jitted scan of one chaos run, "
                            "dense vs compact phase lowering; ckpt_grid:"
                            " grid_prep_s = build_grid_timelines (one "
                            "draw stream per seed, per-config refits), "
                            "baseline = per-(config,seed) "
                            "build_chaos_timeline host replays; shard: "
                            "forced host CPU devices share the "
                            "machine's cores, so gains cap at the "
                            "physical core count")}
        (RESULTS / "bench_sweep_scale.json").write_text(
            json.dumps(payload, indent=2))
        write_summary()


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
