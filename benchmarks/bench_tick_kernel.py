"""Fused Pallas tick-phase benchmark (ISSUE 6 acceptance).

Three studies:

* **kernel** — one fused routing phase (`repro.kernels.tick_phase`) in
  isolation on the deepest SS phase: the jnp reference lowering vs the
  actual Pallas kernel through the interpreter, on a seed-batched
  ``(S, n_tasks)`` state block. On TPU the same call compiles the real
  kernel; on this CPU box the interpret number is a correctness-path
  cost, not a perf claim.
* **engine** — end-to-end warm seed-batch runs (`run_batch`) of the SS
  mega-arena, compact vs pallas phase mode. The pallas run is natively
  seed-batched (no outer vmap; the seed axis is the kernel grid
  dimension), so this measures the fused lowering against the
  row-table compact tick it replaces. Headline:
  ``pallas_tick_speedup`` in results/bench_summary.json.
* **mega** (full mode only) — the 100k-task `nexmark.mega_arena`
  ticking end-to-end in pallas mode, plus a (C=4 failover configs ×
  S=64 seeds) grid over it in ONE `run_config_batch` device pass:
  C·S·n_jobs ≈ 1.07M job-scenarios per pass (the ISSUE 6 scale bar).

Emits CSV rows through benchmarks/run.py and writes
``results/bench_tick_kernel.json`` + refreshes
``results/bench_summary.json``. Quick mode shrinks everything and never
overwrites the tracked JSONs.
"""
from __future__ import annotations

import functools
import json
import pathlib
import time

import jax
import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode

from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import FailoverConfig
from repro.streams.jax_engine import (_Lowered, _enable_x64, run_batch,
                                      run_config_batch)

SPEC = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
FAILOVER = FailoverConfig(mode="region", region_restart_s=20.0)
RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"


def _time(fn, *args, reps: int = 3) -> float:
    """Warm min-of-reps wall seconds of a jitted fn (blocks on result)."""
    jax.block_until_ready(fn(*args))          # compile / warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return min(times)


def kernel_study(n_tasks: int, n_seeds: int, reps: int = 3) -> dict:
    """One fused phase in isolation: ref vs interpret impl on the
    heaviest (largest-D) phase of a packed SS arena."""
    from repro.kernels.tick_phase import (choose_seed_block,
                                          pack_phase_tables, table_bytes,
                                          tick_phase)

    arena = nexmark.ss_arena(n_tasks=n_tasks, parallelism=8, n_hosts=32)
    low = _Lowered(arena, n_hosts=32, dt=0.5, queue_cap=256.0,
                   failover=FAILOVER, ckpt=None, seed=0,
                   phase_mode="pallas")
    fi, ph = max(enumerate(low.tensor.phases), key=lambda p: p[1].D)
    with _enable_x64():
        tb = pack_phase_tables(low.arrays["edges"][fi],
                               low.arrays["qcap"],
                               low.arrays["mode_single"])
        sb = choose_seed_block(n_seeds, low.plan.n_tasks, ph.D,
                               tb["er_idx"].shape[0], table_bytes(tb))
        rng = np.random.default_rng(0)
        produced = jax.numpy.asarray(
            rng.uniform(0, 50.0, (n_seeds, low.plan.n_tasks)))
        alive = jax.numpy.asarray(
            (rng.uniform(size=(n_seeds, low.plan.n_tasks)) > 0.1)
            .astype(float))
        free = jax.numpy.asarray(
            rng.uniform(0, 256.0, (n_seeds, low.plan.n_tasks)))
        rec = {"n_tasks": low.plan.n_tasks, "S": n_seeds, "D": ph.D,
               "phase": fi, "seed_block": sb,
               "table_kib": round(table_bytes(tb) / 1024, 1)}
        for impl in ("ref", "interpret"):
            fn = jax.jit(functools.partial(
                tick_phase, has_blk=ph.B > 0, has_grp=ph.G > 0,
                impl=impl))
            rec[impl + "_us"] = round(
                _time(fn, produced, alive, free, tb, reps=reps) * 1e6, 1)
    return rec


def engine_study(n_tasks: int, n_seeds: int, duration: float,
                 reps: int = 3) -> dict:
    """Warm end-to-end seed-batch wall, compact vs pallas phase mode,
    on the deep-pipeline SS mega-arena."""
    arena = nexmark.ss_arena(n_tasks=n_tasks, parallelism=8, n_hosts=64)
    seeds = list(range(n_seeds))
    rec = {"arena": f"ss_{arena.plan.n_tasks}t", "S": n_seeds,
           "n_jobs": arena.n_jobs, "duration_s": duration}
    for mode in ("compact", "pallas"):
        run_batch(arena, seeds, duration_s=duration, base_spec=SPEC,
                  failover=FAILOVER, phase_mode=mode)   # compile / warm
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            run_batch(arena, seeds, duration_s=duration, base_spec=SPEC,
                      failover=FAILOVER, phase_mode=mode)
            times.append(time.perf_counter() - t0)
        rec[mode + "_warm_s"] = round(min(times), 3)
    rec["pallas_vs_compact_speedup"] = round(
        rec["compact_warm_s"] / rec["pallas_warm_s"], 2)
    return rec


def mega_study(n_tasks: int, n_configs: int, n_seeds: int,
               duration: float) -> dict:
    """100k-task arena end-to-end in pallas mode + the million-job-
    scenario single-pass config grid."""
    arena = nexmark.mega_arena(n_tasks=n_tasks, workload="q12",
                               parallelism=8, n_hosts=256)
    rec = {"arena": f"q12_mega_{arena.plan.n_tasks}t",
           "n_jobs": arena.n_jobs, "n_tasks": arena.plan.n_tasks}

    t0 = time.perf_counter()
    bm = run_batch(arena, range(4), duration_s=duration, base_spec=SPEC,
                   failover=FAILOVER, phase_mode="pallas")
    rec["e2e_tick"] = {
        "S": 4, "duration_s": duration,
        "wall_s": round(time.perf_counter() - t0, 2),
        "dropped_total": float(np.sum(bm.dropped_by_job))}

    grid = [FailoverConfig(mode="region", region_restart_s=float(r))
            for r in np.linspace(10.0, 60.0, n_configs)]
    t0 = time.perf_counter()
    res = run_config_batch(arena, grid, range(n_seeds),
                           duration_s=duration, base_spec=SPEC,
                           phase_mode="pallas")
    wall = time.perf_counter() - t0
    js = n_configs * n_seeds * arena.n_jobs
    rec["grid"] = {"C": n_configs, "S": n_seeds,
                   "duration_s": duration,
                   "wall_s": round(wall, 2),
                   "job_scenarios": js,
                   "job_scenarios_per_s": round(js / wall, 1),
                   "single_device_pass": True,
                   "n_results": len(res)}
    rec["job_scenarios"] = js
    return rec


def run():
    quick = quick_mode()

    krec = kernel_study(n_tasks=448 if quick else 2016,
                        n_seeds=8 if quick else 32)
    yield (f"phase_kernel_ref_{krec['n_tasks']}t", krec["ref_us"],
           f"D={krec['D']};sb={krec['seed_block']}")
    yield (f"phase_kernel_interp_{krec['n_tasks']}t",
           krec["interpret_us"],
           f"interpret/ref={krec['interpret_us'] / krec['ref_us']:.1f}x")

    erec = engine_study(n_tasks=1008 if quick else 9968,
                        n_seeds=8 if quick else 16,
                        duration=30.0 if quick else 60.0)
    yield (f"tick_pallas_{erec['arena']}", erec["pallas_warm_s"] * 1e6,
           f"S={erec['S']};"
           f"vs_compact={erec['pallas_vs_compact_speedup']}x")

    if not quick:
        mrec = mega_study(n_tasks=100_000, n_configs=4, n_seeds=64,
                          duration=20.0)
        yield (f"mega_grid_{mrec['n_tasks']}t",
               mrec["grid"]["wall_s"] * 1e6,
               f"{mrec['grid']['job_scenarios']}job-scen/pass;"
               f"{mrec['grid']['job_scenarios_per_s']}/s")
        RESULTS.mkdir(exist_ok=True)
        payload = {"kernel": krec, "engine": erec, "mega": mrec,
                   "note": ("kernel: one fused phase, jnp ref vs Pallas "
                            "interpreter (CPU box — compiled Pallas "
                            "needs a TPU); engine: warm run_batch wall, "
                            "compact vs natively-seed-batched pallas "
                            "mode; mega: 100k-task arena, (CxS) grid in "
                            "one run_config_batch device pass")}
        (RESULTS / "bench_tick_kernel.json").write_text(
            json.dumps(payload, indent=2))
        from benchmarks.bench_sweep_scale import write_summary
        write_summary()


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
