"""Compile-cost benchmark: trace size and cold-compile time of the
tensorized segment-sum tick vs the legacy unrolled tick, across Q12
mega-arenas of 1k/4k/10k tasks (42/168/416 co-located jobs).

The unrolled tick's jaxpr grows O(ops + edges) — hundreds of jobs make
it untraceable in practice — while the phase-scheduled tensorized tick
keeps a constant op count (the acceptance bar for ISSUE 4). Also runs a
10k-task Q12 (configs × seeds) resiliency sweep through
`chaos_sweep.sweep_configs` to record end-to-end throughput at scale.

Each compiled lowering's record now also carries its XLA cost model —
per-run HLO FLOP/byte estimates through `launch.roofline.kernel_roofline`
(arithmetic intensity + compute/memory bound) and the
`launch.hlo_stats.hlo_op_counts` opcode histogram — alongside the jaxpr
eqn counts, so trace size, emitted op mix, and roofline position travel
together in one record.

Emits the usual CSV rows through benchmarks/run.py and writes
``results/bench_compile.json`` for the perf trajectory. Quick mode
(REPRO_BENCH_QUICK=1) shrinks to one small arena and skips the JSON.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.chaos import ChaosSpec
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep_configs
from repro.streams.engine import FailoverConfig
from repro.streams.jax_engine import (_Lowered, _build_run, _enable_x64,
                                      build_unrolled_run)

FAILOVER = FailoverConfig(mode="region", region_restart_s=20.0)
SPEC = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)


def count_eqns(jaxpr) -> int:
    """Total equation count of a jaxpr including all sub-jaxprs (scan
    bodies, cond branches, …) — the trace-size metric."""
    from jax.core import ClosedJaxpr, Jaxpr

    def sub(v):
        if isinstance(v, ClosedJaxpr):
            return count_eqns(v.jaxpr)
        if isinstance(v, Jaxpr):
            return count_eqns(v)
        if isinstance(v, (list, tuple)):
            return sum(sub(x) for x in v)
        return 0

    n = 0
    for eq in jaxpr.eqns:
        n += 1
        for v in eq.params.values():
            n += sub(v)
    return n


def _measure(run_fn, arrays, state, xs) -> dict:
    """Trace + cold-compile one run fn AOT; report eqns, seconds, and
    the compiled artifact's cost model: HLO FLOP/byte estimates
    (`launch.hlo_stats.cost_stats`) fed through the chip roofline
    (`launch.roofline.kernel_roofline`) plus the HLO opcode histogram
    (`hlo_op_counts`) — so each lowering's record carries *what XLA
    actually emitted*, not just how long it took."""
    from repro.launch.hlo_stats import cost_stats, hlo_op_counts
    from repro.launch.roofline import kernel_roofline

    with _enable_x64():
        t0 = time.perf_counter()
        jaxpr = jax.make_jaxpr(run_fn)(arrays, state, xs)
        trace_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = jax.jit(run_fn).lower(arrays, state, xs).compile()
        compile_s = time.perf_counter() - t0
        cost = cost_stats(compiled)
        roof = kernel_roofline(cost["flops"], cost["bytes_accessed"])
        ops = hlo_op_counts(compiled.as_text())
        del compiled
    top_ops = dict(sorted(ops.items(), key=lambda kv: -kv[1])[:12])
    return {"eqns": count_eqns(jaxpr.jaxpr),
            "trace_s": round(trace_s, 3),
            "compile_s": round(compile_s, 3),
            "hlo_flops": cost["flops"],
            "hlo_bytes": cost["bytes_accessed"],
            "intensity_flops_per_byte":
                round(roof["intensity_flops_per_byte"], 4),
            "roofline_bound": roof["bound"],
            "hlo_op_total": sum(ops.values()),
            "hlo_top_ops": top_ops}


def compile_study(n_tasks: int, n_ticks: int = 4) -> dict:
    arena = nexmark.q12_arena(n_tasks=n_tasks, parallelism=8, n_hosts=64)
    # pinned to the DENSE lowering: this benchmark's record is the
    # tensorized-vs-unrolled comparison; the compact (sparse-phase)
    # lowering is measured by benchmarks/bench_sweep_scale.py
    low = _Lowered(arena, n_hosts=64, dt=0.5, queue_cap=256.0,
                   failover=FAILOVER, ckpt=None, seed=0,
                   phase_mode="dense")
    state, xs, _ = low.prepare(ChaosSpec(seed=0), n_ticks)
    rec = {"n_tasks": arena.plan.n_tasks, "n_jobs": arena.n_jobs,
           "n_ops": len(arena.plan.ops), "n_phases": low.tensor.n_phases,
           "new": _measure(_build_run(low.desc), low.arrays, state, xs)}
    desc_l, arrays_l = low.legacy()
    rec["old"] = _measure(build_unrolled_run(desc_l), arrays_l, state, xs)
    rec["compile_speedup"] = round(
        (rec["old"]["trace_s"] + rec["old"]["compile_s"])
        / max(rec["new"]["trace_s"] + rec["new"]["compile_s"], 1e-9), 2)
    return rec


def sweep_study(n_tasks: int, n_seeds: int, duration: float) -> dict:
    """10k-task Q12 resiliency sweep: a (configs × seeds) grid in one
    device call on the tensorized tick."""
    arena = nexmark.q12_arena(n_tasks=n_tasks, parallelism=8, n_hosts=64)
    grid = [FailoverConfig(mode="region", region_restart_s=r)
            for r in (15.0, 45.0)]
    res = sweep_configs(arena, grid, range(n_seeds), base_spec=SPEC,
                        duration_s=duration)
    return {"n_tasks": arena.plan.n_tasks, "n_jobs": arena.n_jobs,
            "grid": [f"region_restart={r:g}s" for r in (15.0, 45.0)],
            "n_seeds": n_seeds, "duration_s": duration,
            "wall_s": round(res.wall_s, 2),
            "scenarios_per_s": round(res.scenarios_per_s, 2),
            "recovery_p50_s": [round(r["recovery_p50_s"], 2)
                               for r in res.rows()]}


def run():
    quick = quick_mode()
    sizes = [504] if quick else [1008, 4032, 9984]
    records = []
    for n in sizes:
        rec = compile_study(n)
        records.append(rec)
        yield (f"compile_new_{rec['n_tasks']}t",
               rec["new"]["compile_s"] * 1e6,
               f"eqns={rec['new']['eqns']};"
               f"hlo_ops={rec['new']['hlo_op_total']};"
               f"{rec['new']['roofline_bound']}-bound@"
               f"{rec['new']['intensity_flops_per_byte']}f/B")
        yield (f"compile_old_{rec['n_tasks']}t",
               rec["old"]["compile_s"] * 1e6,
               f"eqns={rec['old']['eqns']};"
               f"hlo_ops={rec['old']['hlo_op_total']};"
               f"speedup={rec['compile_speedup']}x")
    sw = sweep_study(sizes[-1] if quick else 9984,
                     n_seeds=4 if quick else 8,
                     duration=20.0 if quick else 30.0)
    yield (f"q12_sweep_{sw['n_tasks']}t", sw["wall_s"] * 1e6,
           f"{sw['scenarios_per_s']}scen/s")
    if not quick:   # quick smoke must not overwrite the tracked record
        out = pathlib.Path(__file__).resolve().parent.parent / "results"
        out.mkdir(exist_ok=True)
        payload = {"compile": records, "q12_sweep": sw,
                   "note": ("trace+compile of one jitted 4-tick scan; "
                            "eqns = recursive jaxpr equation count")}
        (out / "bench_compile.json").write_text(
            json.dumps(payload, indent=2))


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
