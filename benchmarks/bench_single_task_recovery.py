"""Fig 9: QPS under a TaskManager kill at T+300 s on the Sample Stitching
join — baseline region failover vs single-task recovery. Also the jax-trainer
variant (real train steps, virtual time)."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import FailoverConfig, StreamEngine


def run():
    rows = []
    for mode in ("region", "single_task"):
        chaos = ChaosEngine(ChaosSpec(seed=0, host_kill_at=((300.0, 2),)))
        eng = StreamEngine(nexmark.ss(parallelism=8), n_hosts=8, chaos=chaos,
                           failover=FailoverConfig(mode=mode,
                                                   region_restart_s=120.0,
                                                   single_restart_s=3.0))
        t0 = time.perf_counter()
        m = eng.run(900)
        us = (time.perf_counter() - t0) * 1e6
        t = np.array(m.t)
        q = np.array(m.qps["join"])
        steady = np.mean(q[(t > 100) & (t < 295)])
        post = q[(t > 300) & (t < 450)]
        zero_s = float((post == 0).sum() * eng.dt)
        dip = float(post.min() / steady) if steady else 0.0
        loss = m.dropped / max(m.emitted, 1)
        rows.append((f"single_task_recovery/{mode}", us,
                     f"downtime_s={zero_s:.0f};min_qps_frac={dip:.2f};"
                     f"loss={loss:.4%}"))
    return rows


def run_trainer():
    """The jax multi-worker variant (real train steps; slower — separate)."""
    import jax
    from repro.configs import ShapeConfig, get_smoke_arch
    from repro.configs.registry import make_run
    from repro.core.single_task_recovery import (MultiWorkerTrainer,
                                                 RecoveryTiming)
    from repro.models import build

    rows = []
    model = build(get_smoke_arch("stablelm-1.6b"))
    run_cfg = make_run("stablelm-1.6b", "train_4k")
    run_cfg = dataclasses.replace(run_cfg, model=model.cfg,
                                  shape=ShapeConfig("s", 16, 2, "train"))
    for mode in ("global_restart", "single_task"):
        chaos = ChaosEngine(ChaosSpec(seed=0, host_kill_at=((5.0, 1),)))
        tr = MultiWorkerTrainer(model, run_cfg, n_workers=4, mode=mode,
                                step_time_s=1.0, chaos=chaos,
                                timing=RecoveryTiming(global_restore_s=15,
                                                      global_replay_s=15))
        t0 = time.perf_counter()
        trace = tr.run_for(45.0)
        us = (time.perf_counter() - t0) * 1e6
        q = np.array([p["qps"] for p in trace])
        rows.append((f"single_task_recovery/trainer/{mode}", us,
                     f"zero_ticks={(q == 0).sum()};min_frac="
                     f"{q.min() / max(q.max(), 1):.2f}"))
    return rows
