"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = the reproduced headline
metric of that table/figure).

``--quick`` runs a fast smoke subset (sets REPRO_BENCH_QUICK=1, which
modules may honor to shrink their workloads) — used by scripts/ci.sh.
Quick mode must NOT overwrite the tracked ``results/*.json`` perf
records (they are the full-size measurements of record): modules guard
their JSON writes with `quick_mode()`.
"""
from __future__ import annotations

import os
import sys
import traceback


def quick_mode() -> bool:
    """Shared REPRO_BENCH_QUICK parse — one truthiness rule for every
    benchmark module."""
    return os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")

MODULES = [
    "benchmarks.bench_startup",             # Table II + Fig 5
    "benchmarks.bench_adaptive_shuffle",    # Fig 6
    "benchmarks.bench_autoscaling",         # Fig 7
    "benchmarks.bench_region_ckpt",         # Fig 8
    "benchmarks.bench_single_task_recovery",  # Fig 9
    "benchmarks.bench_weakhash",            # §III-A WeakHash
    "benchmarks.bench_hotupdate",           # §III-C HotUpdate
    "benchmarks.bench_lazyload",            # §III-B State LazyLoad
    "benchmarks.bench_engine",              # stream-engine hot path
    "benchmarks.bench_chaos_sweep",         # vmapped jit chaos sweeps
    "benchmarks.bench_colocation",          # multi-job mega-arena sweeps
    "benchmarks.bench_compile",             # tensorized-tick compile cost
    "benchmarks.bench_sweep_scale",         # sparse-phase + sharded grids
    "benchmarks.bench_tick_kernel",         # fused Pallas tick phases
    "benchmarks.bench_replication",         # §IV-A hybrid replication cube
    "benchmarks.bench_deployment",          # canary/rolling deployment drills
    "benchmarks.bench_traffic",             # traffic dynamics + DS2 autoscaling
    "benchmarks.bench_serve",               # sweep-as-a-service TTFR + throughput
    "benchmarks.bench_kernels",             # §V-C micro benchmarking
]

QUICK_MODULES = [
    "benchmarks.bench_engine",              # vectorized vs reference engine
    "benchmarks.bench_chaos_sweep",         # vmapped jit chaos sweeps
    "benchmarks.bench_colocation",          # multi-job mega-arena sweeps
    "benchmarks.bench_compile",             # tensorized-tick compile cost
    "benchmarks.bench_sweep_scale",         # sparse-phase + sharded grids
    "benchmarks.bench_tick_kernel",         # fused Pallas tick phases
    "benchmarks.bench_replication",         # hybrid replication cube
    "benchmarks.bench_deployment",          # canary/rolling deployment drills
    "benchmarks.bench_traffic",             # traffic dynamics + DS2 autoscaling
    "benchmarks.bench_serve",               # sweep-as-a-service TTFR + throughput
    "benchmarks.bench_weakhash",            # WeakHash assignment path
    "benchmarks.bench_hotupdate",           # pure-python, fast
]


def main() -> None:
    import importlib

    quick = "--quick" in sys.argv[1:]
    if quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    print("name,us_per_call,derived")
    failed: list[tuple[str, str, str]] = []
    for mod_name in (QUICK_MODULES if quick else MODULES):
        # step the generator explicitly: a bench that dies mid-module
        # keeps the rows it already produced, the failure row names the
        # exact bench (module + last completed row), and the remaining
        # modules still run
        last = "<import>"
        try:
            it = iter(importlib.import_module(mod_name).run())
        except Exception:
            failed.append((mod_name, last, traceback.format_exc(limit=2)))
            print(f"{mod_name},ERROR,import/setup failed", flush=True)
            continue
        while True:
            try:
                name, us, derived = next(it)
            except StopIteration:
                break
            except Exception:
                failed.append((mod_name, last,
                               traceback.format_exc(limit=2)))
                print(f"{mod_name},ERROR,failed after row {last!r}",
                      flush=True)
                break
            print(f"{name},{us:.1f},{derived}", flush=True)
            last = name
    if failed:
        print(f"\n{len(failed)} bench module(s) FAILED:", file=sys.stderr)
        for mod_name, last, tb in failed:
            print(f"--- {mod_name} (after row {last!r})\n{tb}",
                  file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
