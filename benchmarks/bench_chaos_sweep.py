"""Chaos-sweep throughput: scenarios/s of the vmapped `jit` sweep
(`streams/chaos_sweep.py`) vs sequential numpy-engine drills on the same
scenario batch.

Emits the usual CSV rows through benchmarks/run.py and writes
``results/bench_chaos_sweep.json`` for the perf trajectory. Quick mode
(REPRO_BENCH_QUICK=1) shrinks the batch and horizon so the module runs
in a few seconds on CPU.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.streams import nexmark
from repro.streams.chaos_sweep import sweep
from repro.streams.engine import FailoverConfig, StreamEngine

BASE_SPEC = ChaosSpec(host_kill_prob_per_s=0.004, straggler_frac=0.2)
FAILOVER = FailoverConfig(mode="region", region_restart_s=20.0)


def _numpy_scenarios_per_s(graph, duration_s: float, n_probe: int) -> float:
    import dataclasses
    t0 = time.perf_counter()
    for s in range(n_probe):
        eng = StreamEngine(
            graph, n_hosts=8,
            chaos=ChaosEngine(dataclasses.replace(BASE_SPEC, seed=s)),
            failover=FAILOVER)
        eng.run(duration_s)
    return n_probe / (time.perf_counter() - t0)


def run():
    quick = quick_mode()
    n_seeds = 32 if quick else 256
    duration = 60.0 if quick else 120.0
    graph = nexmark.q2(parallelism=8, partitioner="weakhash", n_groups=4)

    # cold (includes trace+compile) then warm sweep
    res_cold = sweep(graph, range(n_seeds), base_spec=BASE_SPEC,
                     duration_s=duration, n_hosts=8, failover=FAILOVER)
    res = sweep(graph, range(n_seeds), base_spec=BASE_SPEC,
                duration_s=duration, n_hosts=8, failover=FAILOVER)
    np_rate = _numpy_scenarios_per_s(graph, duration, 2 if quick else 4)
    agg = res.aggregate()
    ticks_s = n_seeds * res.n_ticks / res.wall_s
    speedup = res.scenarios_per_s / np_rate

    rows = [(f"chaos_sweep/q2_weakhash/{n_seeds}seeds",
             1e6 / res.scenarios_per_s,
             f"scenarios_s={res.scenarios_per_s:.0f};"
             f"np_scenarios_s={np_rate:.1f};speedup={speedup:.0f}x;"
             f"ticks_s={ticks_s:.0f};"
             f"recovery_p95_s={agg['recovery_p95_s']:.1f}")]
    if not quick:   # quick smoke must not overwrite the tracked record
        record = {
            "n_seeds": n_seeds, "duration_s": duration,
            "n_ticks": res.n_ticks,
            "cold_wall_s": res_cold.wall_s, "warm_wall_s": res.wall_s,
            "scenarios_per_s": res.scenarios_per_s,
            "numpy_scenarios_per_s": np_rate, "speedup": speedup,
            "aggregate": agg,
        }
        out = pathlib.Path("results")
        out.mkdir(parents=True, exist_ok=True)
        (out / "bench_chaos_sweep.json").write_text(
            json.dumps(record, indent=1))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
