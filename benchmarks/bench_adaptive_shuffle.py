"""Fig 6: Nexmark Q2 throughput with 10% straggler tasks (1000× slower),
rebalance (baseline) vs backlog-based shuffle vs group-rescale."""
from __future__ import annotations

import time

import numpy as np

from repro.streams import nexmark
from repro.streams.engine import StreamEngine

SCALES = (32, 128, 512)  # "TMs": scales the parallel instances


def _throughput(partitioner: str, par: int, seed: int = 0) -> float:
    n_groups = max(par // 4, 1) if partitioner == "group_rescale" else 1
    g = nexmark.q2(parallelism=par, source_rate=0.8e6,
                   service_rate=0.8e6 / par * 1.4, partitioner=partitioner,
                   n_groups=n_groups)
    slow = {t: 1e-3 for t in range(par, 2 * par)[::10]}  # 10% of filter tasks
    eng = StreamEngine(g, n_hosts=par, seed=seed, task_speed_override=slow)
    m = eng.run(120)
    return float(np.mean(m.qps["filter"][100:]))


def run():
    rows = []
    for tms in SCALES:
        par = max(8, tms // 4)
        for part in ("rebalance", "backlog", "group_rescale"):
            t0 = time.perf_counter()
            qps = _throughput(part, par)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"adaptive_shuffle/{part}/{tms}tm", us,
                         f"kqps={qps/1e3:.0f}"))
    return rows
