"""Fig 8: checkpoint success rate w/ and w/o region checkpointing on the DS
job — 5% slow-upload injection, 30 s interval, 12 h run (paper: 53.9% vs
93.5%)."""
from __future__ import annotations

import time

from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.streams import nexmark
from repro.streams.engine import CheckpointConfig, StreamEngine


def run():
    rows = []
    for mode in ("global", "region"):
        chaos = ChaosEngine(ChaosSpec(seed=2, storage_slow_prob=0.05,
                                      storage_slow_factor=10))
        eng = StreamEngine(nexmark.ds(parallelism=6), n_hosts=6, chaos=chaos,
                           ckpt=CheckpointConfig(interval_s=30, mode=mode))
        t0 = time.perf_counter()
        m = eng.run(43_200)
        us = (time.perf_counter() - t0) * 1e6
        rate = m.ckpt_success / max(m.ckpt_attempts, 1)
        rows.append((f"region_ckpt/{mode}", us,
                     f"success={m.ckpt_success}/{m.ckpt_attempts}"
                     f"={rate:.1%}"))
    return rows
