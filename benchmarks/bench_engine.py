"""Stream-engine hot-path benchmark: ticks/sec of the vectorized
routing-plan engine vs the pre-refactor per-edge interpreter
(`streams/reference_engine.py`), at 100 / 1k / 10k tasks.

The graph mixes the paper's partitioners (hash keyBy with Zipf skew,
WeakHash groups, backlog shuffle, Group-Rescale) so every routing path is
on the clock. Emits the usual CSV rows through benchmarks/run.py and
additionally writes ``results/bench_engine.json`` for the perf trajectory.

Quick mode (REPRO_BENCH_QUICK=1 or --quick on run.py) drops the 10k-task
cell and shrinks tick counts so the whole module runs in a few seconds.
"""
from __future__ import annotations

import json
import pathlib
import time

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.streams.engine import StreamEngine
from repro.streams.graph import LogicalEdge, LogicalGraph, LogicalOp
from repro.streams.reference_engine import ReferenceStreamEngine


def bench_graph(n_tasks: int) -> LogicalGraph:
    """5-op chain exercising hash / weakhash / backlog / group_rescale."""
    par = max(n_tasks // 5, 1)
    sr = 1.5e5
    return LogicalGraph(
        "bench_mixed",
        ops=(LogicalOp("source", par, sr, is_source=True, source_rate=0.8e6),
             LogicalOp("keyed", par, sr, selectivity=0.9),
             LogicalOp("agg", par, sr, selectivity=0.5),
             LogicalOp("writer", par, sr, selectivity=1.0),
             LogicalOp("sink", par, sr)),
        edges=(LogicalEdge("source", "keyed", "hash", key_skew_zipf=0.8),
               LogicalEdge("keyed", "agg", "weakhash", n_groups=8),
               LogicalEdge("agg", "writer", "backlog"),
               LogicalEdge("writer", "sink", "group_rescale", n_groups=8)))


def _ticks_per_sec(cls, n_tasks: int, n_ticks: int, repeats: int = 3) -> float:
    eng = cls(bench_graph(n_tasks), n_hosts=max(n_tasks // 10, 4))
    eng.run(5 * eng.dt)  # warm caches / buffers
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n_ticks):
            eng.tick()
        best = max(best, n_ticks / (time.perf_counter() - t0))
    return best


def run():
    quick = quick_mode()
    cells = [(100, 500, 4000), (1000, 60, 4000)]
    if not quick:
        cells = [(100, 1000, 10000), (1000, 150, 10000), (10000, 10, 1500)]
    rows, record = [], {"cells": []}
    for n_tasks, n_ref, n_vec in cells:
        ref = _ticks_per_sec(ReferenceStreamEngine, n_tasks, n_ref,
                             repeats=1 if quick else 3)
        vec = _ticks_per_sec(StreamEngine, n_tasks, n_vec,
                             repeats=1 if quick else 3)
        speedup = vec / ref
        rows.append((f"engine/tick/{n_tasks}tasks", 1e6 / vec,
                     f"ticks_s={vec:.0f};ref_ticks_s={ref:.0f};"
                     f"speedup={speedup:.1f}x"))
        record["cells"].append({"n_tasks": n_tasks, "ticks_s": vec,
                                "ref_ticks_s": ref, "speedup": speedup})
    if not quick:   # quick smoke must not overwrite the tracked record
        out = pathlib.Path("results")
        out.mkdir(parents=True, exist_ok=True)
        (out / "bench_engine.json").write_text(json.dumps(record, indent=1))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
