"""§III-C HotUpdate: cold vs hot restart latency on a real jit'd step
(executable cache + device-state reuse)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_smoke_arch
from repro.core.hotupdate import HotUpdateManager
from repro.dist import NO_SHARDING
from repro.models import build


def run():
    model = build(get_smoke_arch("stablelm-12b"))
    params = model.init(jax.random.PRNGKey(0))
    batch = model.demo_batch(ShapeConfig("b", 64, 2, "train"))
    mgr = HotUpdateManager()

    def make_step(scale=1.0):
        def build_step():
            @jax.jit
            def step(state, batch):
                loss, _ = model.loss_fn(state, batch, NO_SHARDING,
                                        remat="none")
                new = jax.tree.map(lambda p: (p - 1e-3 * scale * p).astype(p.dtype),
                                   state)
                return new, loss
            return step
        return build_step

    t0 = time.perf_counter()
    cold = mgr.deploy("v1", make_step(1.0), params, (batch,),
                      reuse_state=False)
    hot_same = mgr.deploy("v1", make_step(1.0), params, (batch,))
    hot_new = mgr.deploy("v2", make_step(0.5), params, (batch,))
    us = (time.perf_counter() - t0) * 1e6
    return [("hotupdate/restart", us,
             f"cold_s={cold.total_s:.2f};hot_same_s={hot_same.total_s:.3f};"
             f"hot_newlogic_s={hot_new.total_s:.2f};"
             f"speedup={cold.total_s / max(hot_same.total_s, 1e-9):.0f}x")]
