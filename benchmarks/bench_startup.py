"""Table II + Fig 5: job startup overhead (parse / alloc / deploy) across
cluster scales, baseline vs StreamShield."""
from __future__ import annotations

import time

from repro.cluster.simulator import ClusterSim, nexmark_edges
from repro.core.startup import StartupConfig

SCALES = (512, 1024, 2048)


def run():
    rows = []
    for n in SCALES:
        edges = nexmark_edges(64, n_ops=3)
        for label, cfg in (("baseline", StartupConfig.baseline()),
                           ("streamshield", StartupConfig())):
            t0 = time.perf_counter()
            ph = ClusterSim(n, seed=1).startup(edges, cfg)
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"startup/{label}/{n}tm", us,
                         f"parse_ms={ph.parse_ms:.0f};"
                         f"alloc_ms={ph.alloc_ms:.0f};"
                         f"deploy_ms={ph.deploy_ms:.0f};"
                         f"total_ms={ph.total_ms:.0f}"))
        # HotUpdate variant (paper: restart latency → ~20 s)
        t0 = time.perf_counter()
        ph = ClusterSim(n, seed=1).startup(edges, StartupConfig(hotupdate=True))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"startup/hotupdate/{n}tm", us,
                     f"total_ms={ph.total_ms:.0f}"))
    return rows
