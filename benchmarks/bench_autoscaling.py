"""Fig 7: DS2 autoscaling under the DS workload's variable input rate
(1→7 M/s over a compressed 55 h trace): parallelism must track the rate."""
from __future__ import annotations

import time

import numpy as np

from repro.core.autoscaler import DS2Scaler, OpMetrics, ScalerConfig


def ds_trace(hours: float = 55.0, dt_h: float = 0.25) -> np.ndarray:
    """Input-rate trace shaped like Fig 7: diurnal swings + bursts, 1–7 M/s."""
    t = np.arange(0, hours, dt_h)
    base = 3.2e6 + 1.8e6 * np.sin(2 * np.pi * t / 24.0 - 1.1)
    burst = 2.5e6 * np.exp(-0.5 * ((t - 47) / 3.5) ** 2)
    dip = -1.6e6 * np.exp(-0.5 * ((t - 15) / 2.0) ** 2)
    rng = np.random.default_rng(0)
    noise = rng.normal(0, 1.2e5, len(t))
    return np.clip(base + burst + dip + noise, 0.9e6, 7.2e6)


def simulate(true_rate_per_task: float = 24_000.0):
    cfg = ScalerConfig(cooldown_s=1800, hysteresis=0.1, ewma_alpha=0.4,
                       max_actions_per_hour=1000)
    sc = DS2Scaler(cfg)
    trace = ds_trace()
    par = 150
    pars, backlog = [], 0.0
    for i, rate in enumerate(trace):
        t = i * 900.0  # 15-min windows
        capacity = par * true_rate_per_task
        processed = min(rate, capacity) * 900
        backlog = max(0.0, backlog + (rate - capacity) * 900)
        m = OpMetrics("ds_sink", rate, processed,
                      busy_time_s=processed / true_rate_per_task,
                      parallelism=par, backlog=backlog,
                      backpressured=backlog > 0)
        for d in sc.observe(t, [m]):
            par = d.new
            sc.notify_result("ds_sink", t, success=True)
        pars.append(par)
    return trace, np.array(pars), sc


def run():
    t0 = time.perf_counter()
    trace, pars, sc = simulate()
    us = (time.perf_counter() - t0) * 1e6
    corr = float(np.corrcoef(trace, pars)[0, 1])
    return [("autoscaling/ds2_trace", us,
             f"corr={corr:.3f};par_min={pars.min()};par_max={pars.max()};"
             f"actions={len(sc.history)}")]
