"""Traffic-dynamics cube (capacity-planning drills): SLO violation /
lost work / resource-seconds over scaler-config × traffic-pattern ×
failover-mode, produced by ONE `sweep_configs` device call
(`streams.chaos_sweep.traffic_sweep`), plus the flash-crowd recovery
headline — how much faster the in-trace DS2 controller drains a 3x
surge than a frozen-parallelism fleet, and at what resource bill (the
elasticity-vs-cost framing of arXiv:2404.06203).

Emits the usual CSV rows through benchmarks/run.py and writes
``results/bench_traffic.json`` for the perf trajectory. Quick mode
(REPRO_BENCH_QUICK=1) shrinks the cube and horizon so the module runs in
a few seconds on CPU — and, per the harness contract, skips the JSON
write.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.chaos import ChaosSpec, timeline_build_count
from repro.streams import nexmark
from repro.streams.chaos_sweep import traffic_sweep
from repro.streams.engine import AutoscaleConfig, FailoverConfig
from repro.streams.jax_engine import JaxStreamEngine

FO = FailoverConfig(mode="region", detect_s=1.0)
DS2 = AutoscaleConfig(interval_s=5.0, cooldown_s=10.0)


def _scalers() -> dict[str, AutoscaleConfig | None]:
    return {
        "frozen": None,                       # fixed-provisioning control
        "ds2": DS2,
        # an eager tuning point: shorter windows, tighter hysteresis —
        # tracks the surge faster but risks the thrash guard
        "ds2-eager": AutoscaleConfig(interval_s=3.0, cooldown_s=5.0,
                                     hysteresis=0.08, ewma_alpha=0.6),
    }


def _drain_s(backlog: np.ndarray, dt: float, t_flash: float) -> float:
    """Time from flash onset until the downstream backlog last drains
    under 1 record — the flash-crowd recovery time. Sources never
    rescale (their ingest capacity is the offered-load boundary), so
    elasticity shows up downstream of them."""
    idx = np.nonzero(backlog > 1.0)[0]
    if idx.size == 0:
        return 0.0
    return max(0.0, (idx[-1] + 1) * dt - t_flash)


def run():
    quick = quick_mode()
    n_seeds = 4 if quick else 24
    duration = 90.0 if quick else 200.0
    g = nexmark.q3()

    # headline: a clean 3x flash crowd (no failure burst — a region
    # restart wipes the source queues and would zero the lag-based
    # recovery metric), frozen vs DS2
    t_flash = 30.0 if quick else 90.0
    spec = nexmark.traffic_drill_spec(
        seed=5, flash=((t_flash, 10.0, 30.0, 3.0),), burst_t=None)
    eng = {name: JaxStreamEngine(g, chaos=spec, failover=FO,
                                 autoscale=cfg, phase_mode="compact")
           for name, cfg in (("frozen", None), ("ds2", DS2))}
    res = {name: e.run(duration) for name, e in eng.items()}
    dt = 0.5
    srcs = {o.name for o in g.ops if o.is_source}
    down = {name: sum(np.asarray(m.backlog[n]) for n in m.backlog
                      if n not in srcs)
            for name, m in res.items()}
    rec = {name: _drain_s(bk, dt, t_flash) for name, bk in down.items()}
    # backlog area = record-seconds of queueing delay, the lost-work
    # proxy the surge costs a frozen fleet
    area = {name: float(bk.sum()) * dt for name, bk in down.items()}
    cost = {name: float(m.resource_s) for name, m in res.items()}

    # the cube: scaler × traffic × failover × seed from ONE device call
    traffics = {
        "diurnal": {"diurnal": ((0.35, 240.0, 0.0),)},
        "flash": {"flash": ((t_flash, 10.0, 30.0, 3.0),)},
        "both": (((0.35, 240.0, 0.0),), ((t_flash, 10.0, 30.0, 3.0),)),
    }
    failovers = {"region": FO}
    if not quick:
        failovers["single"] = FailoverConfig(mode="single_task",
                                             detect_s=1.0,
                                             single_restart_s=2.0)
    base = ChaosSpec(seed=0, host_kill_prob_per_s=0.001)
    c0 = timeline_build_count()
    cold_t0 = time.perf_counter()
    traffic_sweep(g, range(n_seeds), base_spec=base, duration_s=duration,
                  scalers=_scalers(), traffics=traffics,
                  failovers=failovers)
    cold_wall = time.perf_counter() - cold_t0
    cube = traffic_sweep(g, range(n_seeds), base_spec=base,
                         duration_s=duration, scalers=_scalers(),
                         traffics=traffics, failovers=failovers)
    builds = timeline_build_count() - c0
    n_cells = cube.recovery.size

    rows = [(f"traffic/q3/{n_cells}cells",
             1e6 * cube.grid.wall_s / n_cells,
             f"cells={n_cells};cells_s={n_cells / cube.grid.wall_s:.0f};"
             f"flash_recovery_frozen_s={rec['frozen']:.1f};"
             f"flash_recovery_ds2_s={rec['ds2']:.1f};"
             f"lost_work_x={area['frozen'] / max(area['ds2'], 1e-9):.2f};"
             f"ds2_cost_x={cost['ds2'] / cost['frozen']:.2f};"
             f"thrash_frac_eager="
             f"{float(cube.thrash_frac[2].mean()):.2f};"
             f"timeline_builds={builds}")]
    if not quick:   # quick smoke must not overwrite the tracked record
        record = {
            "n_seeds": n_seeds, "duration_s": duration,
            "scalers": cube.scalers, "traffics": cube.traffics,
            "failovers": cube.failovers,
            "cold_wall_s": cold_wall, "warm_wall_s": cube.grid.wall_s,
            "cells_per_s": n_cells / cube.grid.wall_s,
            "timeline_builds": builds,
            "flash_recovery_s": rec, "backlog_area_rec_s": area,
            "resource_s": cost,
            "slo_mean": np.asarray(cube.slo).mean(-1).tolist(),
            "lost_mean": np.asarray(cube.lost).mean(-1).tolist(),
            "cost_mean": np.asarray(cube.cost).mean(-1).tolist(),
            "rescales_mean": np.asarray(cube.rescales).mean(-1).tolist(),
            "thrash_frac": np.asarray(cube.thrash_frac).tolist(),
        }
        out = pathlib.Path("results")
        out.mkdir(parents=True, exist_ok=True)
        (out / "bench_traffic.json").write_text(
            json.dumps(record, indent=1))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
