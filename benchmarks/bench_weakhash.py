"""§III-A WeakHash: hot-key diffusion. Zipf-skewed keys → per-task load CV
under strict hash vs WeakHash (bounded groups, load-aware), plus the MoE
token-path variant (hot expert overflow / drop rates)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.weakhash import load_cv, strong_hash, weakhash_assign
from repro.kernels.weakhash_route import ref as route_ref


def run():
    rows = []
    rng = np.random.default_rng(0)
    keys = rng.zipf(1.2, 100_000) % 8192
    for n_tasks, n_groups in ((32, 8), (128, 16)):
        t0 = time.perf_counter()
        cv_s = load_cv(strong_hash(keys, n_tasks), n_tasks)
        cv_w = load_cv(weakhash_assign(keys, n_tasks, n_groups), n_tasks)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"weakhash/keys/{n_tasks}tasks", us,
                     f"cv_strong={cv_s:.3f};cv_weak={cv_w:.3f};"
                     f"reduction={1 - cv_w / cv_s:.1%}"))

    # MoE token path: hot expert
    T, E = 8192, 64
    logits = rng.normal(size=(T, E)).astype(np.float32)
    logits[:, 7] += 3.0
    keyz = jnp.asarray(rng.integers(0, 1 << 20, T), jnp.int32)
    cap = 2 * T // E
    t0 = time.perf_counter()
    strict = route_ref.weakhash_route(jnp.asarray(logits), top_k=2,
                                      capacity=cap, mode="strict")
    weak = route_ref.weakhash_route(jnp.asarray(logits), top_k=2,
                                    capacity=cap, n_groups=16,
                                    mode="weakhash", token_keys=keyz)
    us = (time.perf_counter() - t0) * 1e6
    rows.append((f"weakhash/moe/{E}e", us,
                 f"max_demand_strict={float(strict.demand.max()):.0f};"
                 f"max_demand_weak={float(weak.demand.max()):.0f};"
                 f"drop_strict={1 - float(strict.keep.mean()):.2%};"
                 f"drop_weak={1 - float(weak.keep.mean()):.2%}"))
    return rows
