"""§III-A WeakHash: hot-key diffusion. Zipf-skewed keys → per-task load CV
under strict hash vs WeakHash (bounded groups, load-aware), plus the MoE
token-path variant (hot expert overflow / drop rates) and the demand
carry-forward approximation study (single-pass kernel vs exact global
demand; per-expert load CV over a stream of batches, recorded in
``results/weakhash_carry_forward.json`` — the ROADMAP open item)."""
from __future__ import annotations

import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.run import quick_mode
except ImportError:      # standalone: sys.path[0] is benchmarks/
    from run import quick_mode
from repro.core.weakhash import load_cv, strong_hash, weakhash_assign
from repro.kernels.weakhash_route import ref as route_ref


def _expert_load_cv(demand: np.ndarray) -> float:
    m = demand.mean()
    return float(demand.std() / m) if m > 0 else 0.0


def carry_forward_study(n_batches: int = 6, T: int = 1024, E: int = 32,
                        G: int = 8, top_k: int = 2,
                        block_t: int = 256) -> dict:
    """Routing-quality delta of the single-pass carry-forward kernel.

    Streams `n_batches` hot-keyed batches through the fused kernel twice
    — exact global demand (two logits reads for nt > 1) vs carry-forward
    (previous batch's demand + running tile histogram, one read) — and
    compares the per-expert selection-load CV. Runs in Pallas interpret
    mode so the measurement works on any backend."""
    from repro.kernels.weakhash_route import kernel as K

    rng = np.random.default_rng(42)
    cap = 2 * T // E
    prior = None
    cv_exact, cv_carry, disagree = [], [], []
    for _ in range(n_batches):
        logits = rng.normal(size=(T, E)).astype(np.float32)
        logits[:, rng.integers(0, E)] += 2.5      # a migrating hot expert
        keys = jnp.asarray(rng.integers(0, 1 << 20, T), jnp.int32)
        lg = jnp.asarray(logits)
        ex = K.weakhash_route_ints(lg, top_k=top_k, capacity=cap,
                                   n_groups=G, token_keys=keys,
                                   block_t=block_t, interpret=True)
        cf = K.weakhash_route_ints(lg, top_k=top_k, capacity=cap,
                                   n_groups=G, token_keys=keys,
                                   block_t=block_t, interpret=True,
                                   carry_forward=True, prior_demand=prior)
        prior = cf[3]                             # chain the batches
        sel_ex = np.bincount(np.asarray(ex[0]).ravel(), minlength=E)
        sel_cf = np.bincount(np.asarray(cf[0]).ravel(), minlength=E)
        cv_exact.append(_expert_load_cv(sel_ex))
        cv_carry.append(_expert_load_cv(sel_cf))
        disagree.append(float(np.mean(np.asarray(ex[0]) !=
                                      np.asarray(cf[0]))))
    mean_ex = float(np.mean(cv_exact))
    mean_cf = float(np.mean(cv_carry))
    return {
        "config": {"n_batches": n_batches, "T": T, "E": E, "n_groups": G,
                   "top_k": top_k, "block_t": block_t,
                   "nt": T // block_t, "capacity": cap},
        "load_cv_exact": mean_ex,
        "load_cv_carry_forward": mean_cf,
        "load_cv_delta": mean_cf - mean_ex,
        "load_cv_rel_delta": (mean_cf - mean_ex) / max(mean_ex, 1e-9),
        "selection_disagreement_frac": float(np.mean(disagree)),
        "per_batch": {"cv_exact": cv_exact, "cv_carry": cv_carry},
    }


def run():
    rows = []
    rng = np.random.default_rng(0)
    keys = rng.zipf(1.2, 100_000) % 8192
    for n_tasks, n_groups in ((32, 8), (128, 16)):
        t0 = time.perf_counter()
        cv_s = load_cv(strong_hash(keys, n_tasks), n_tasks)
        cv_w = load_cv(weakhash_assign(keys, n_tasks, n_groups), n_tasks)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"weakhash/keys/{n_tasks}tasks", us,
                     f"cv_strong={cv_s:.3f};cv_weak={cv_w:.3f};"
                     f"reduction={1 - cv_w / cv_s:.1%}"))

    # MoE token path: hot expert
    T, E = 8192, 64
    logits = rng.normal(size=(T, E)).astype(np.float32)
    logits[:, 7] += 3.0
    keyz = jnp.asarray(rng.integers(0, 1 << 20, T), jnp.int32)
    cap = 2 * T // E
    t0 = time.perf_counter()
    strict = route_ref.weakhash_route(jnp.asarray(logits), top_k=2,
                                      capacity=cap, mode="strict")
    weak = route_ref.weakhash_route(jnp.asarray(logits), top_k=2,
                                    capacity=cap, n_groups=16,
                                    mode="weakhash", token_keys=keyz)
    us = (time.perf_counter() - t0) * 1e6
    rows.append((f"weakhash/moe/{E}e", us,
                 f"max_demand_strict={float(strict.demand.max()):.0f};"
                 f"max_demand_weak={float(weak.demand.max()):.0f};"
                 f"drop_strict={1 - float(strict.keep.mean()):.2%};"
                 f"drop_weak={1 - float(weak.keep.mean()):.2%}"))

    # demand carry-forward approximation (single-pass kernel) vs exact
    quick = quick_mode()
    t0 = time.perf_counter()
    study = carry_forward_study(n_batches=3 if quick else 6,
                                T=512 if quick else 1024)
    us = (time.perf_counter() - t0) * 1e6
    if not quick:   # the quality record tracks the full-size study only
        out = pathlib.Path("results")
        out.mkdir(parents=True, exist_ok=True)
        (out / "weakhash_carry_forward.json").write_text(
            json.dumps(study, indent=1))
    rows.append((f"weakhash/carry_forward/nt{study['config']['nt']}", us,
                 f"cv_exact={study['load_cv_exact']:.3f};"
                 f"cv_carry={study['load_cv_carry_forward']:.3f};"
                 f"rel_delta={study['load_cv_rel_delta']:+.1%};"
                 f"disagree={study['selection_disagreement_frac']:.2%}"))
    return rows
