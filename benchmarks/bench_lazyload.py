"""§III-B State LazyLoad: time-to-first-layer-ready / full-restore overlap —
eager restore vs priority-ordered lazy restore with simulated HDFS latency."""
from __future__ import annotations

import time

import jax

from repro.ckpt.storage import SimHDFS
from repro.configs import get_smoke_arch
from repro.core import regions as R
from repro.core.chaos import ChaosEngine, ChaosSpec
from repro.core.clock import WallClock
from repro.core.lazyload import LazyRestorer
from repro.core.region_checkpoint import RegionCheckpointer
from repro.models import build


def run(tmpdir: str = "/tmp/repro-lazyload"):
    model = build(get_smoke_arch("granite-34b"))
    params = model.init(jax.random.PRNGKey(0))
    regions = R.partition_regions(model.param_specs(), 8)
    # slow-ish storage so the overlap is visible (wall clock: threads overlap)
    store = SimHDFS(tmpdir, clock=WallClock(),
                    chaos=ChaosEngine(ChaosSpec(seed=0)),
                    bandwidth_bps=5e6, base_latency_s=0.01)
    ck = RegionCheckpointer(store, "lazy-bench", regions)
    ck.save(1, params)

    t0 = time.perf_counter()
    ck.restore(params, gamma="full")
    eager_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    lazy = LazyRestorer(ck, params, gamma="full",
                        priority=list(range(len(regions))), max_workers=4)
    lazy.wait_region(0)
    first_s = time.perf_counter() - t0
    lazy.wait_all()
    total_s = time.perf_counter() - t0
    return [("lazyload/restore", total_s * 1e6,
             f"eager_s={eager_s:.2f};first_region_s={first_s:.2f};"
             f"lazy_total_s={total_s:.2f};"
             f"ttfr_speedup={eager_s / max(first_s, 1e-9):.1f}x")]
